//! Analytic photonic-simulation backend: [`HostBackend`] numerics, with
//! per-frame latency charged from the accelerator architecture model
//! instead of host wall-clock.
//!
//! This is the execution substrate the paper's evaluation actually reports:
//! the Fig. 9/11 delay model ([`crate::arch`] schedule + component
//! constants) decides how long a frame takes on the five-core photonic
//! accelerator, while the host merely computes the reference numerics. A
//! `--backend sim` serving run therefore produces a `ServeReport` whose
//! latency column is photonic-core time (energy was always modeled, for
//! every backend), making near-sensor operating points comparable across
//! machines regardless of host speed.
//!
//! Latency is reported **per stage** ([`ModeledStages`]): the MGNet front
//! end and the backbone are separate passes on the accelerator, and the
//! serving metrics record them under separate `"modeled_mgnet"` /
//! `"modeled_backbone"` stages. The model is also **batch-aware**: a frame
//! that rides a bucket-major batch behind its group's first frame reuses
//! the already-programmed **backbone** MR weight banks, so its backbone
//! stage drops by the weight-streaming share
//! ([`crate::energy::AcceleratorModel::weight_stream_delay_s`]) — modeled
//! time per frame *decreases* with batch size, which is the
//! dispatch-amortization effect batched photonic execution exists for.
//! The MGNet stage is never discounted: MGNet runs per frame at route
//! time, interleaved with other buckets' batches, so its banks are
//! reprogrammed regardless of batching.
//!
//! Modeled latencies are cached per kept-patch count: the delay schedule is
//! orders of magnitude more expensive than the energy model (see
//! `AcceleratorModel::frame_energy`), so it must never run per frame.

use anyhow::Result;

use super::host::{ArtifactSpec, HostBackend, HostConfig};
use super::{Backend, ModeledStages, TensorRef};
use crate::energy::AcceleratorModel;
use crate::vit::{MgnetConfig, VitConfig};

/// `(first_in_batch, follower)` modeled latency pair for one stage.
#[derive(Debug, Clone, Copy)]
struct StagePair {
    first_s: f64,
    follow_s: f64,
}

impl StagePair {
    fn pick(&self, first_in_batch: bool) -> f64 {
        if first_in_batch {
            self.first_s
        } else {
            self.follow_s
        }
    }
}

/// [`Backend`] that wraps [`HostBackend`] for execution and overlays
/// modeled photonic frame latency.
#[derive(Debug)]
pub struct SimBackend {
    inner: HostBackend,
    model: AcceleratorModel,
    /// Backbone/MGNet configs, captured from the artifact names at load
    /// time (the first loaded backbone defines the operating point).
    backbone: Option<VitConfig>,
    mgnet: Option<MgnetConfig>,
    /// Modeled MGNet front-end latency (full grid; masked path only).
    /// Batch-independent: MGNet executes per frame at route time.
    mgnet_latency: Option<f64>,
    /// Modeled masked backbone latency by kept-patch count (index = kept).
    masked_latency: Vec<Option<StagePair>>,
    /// Modeled unmasked full-grid latency.
    full_latency: Option<StagePair>,
}

impl SimBackend {
    pub fn new(host: HostConfig) -> Self {
        Self::with_model(host, AcceleratorModel::default())
    }

    pub fn with_model(host: HostConfig, model: AcceleratorModel) -> Self {
        SimBackend {
            inner: HostBackend::new(host),
            model,
            backbone: None,
            mgnet: None,
            mgnet_latency: None,
            masked_latency: Vec::new(),
            full_latency: None,
        }
    }

    /// The architecture model charging the latency.
    pub fn model(&self) -> &AcceleratorModel {
        &self.model
    }

    /// Model one pass of `cfg` at `kept` patches: full latency for a
    /// batch-first frame, and the follower latency with the weight-stream
    /// share amortized away.
    fn stage_pair(&self, cfg: &VitConfig, kept: usize) -> StagePair {
        let first_s = self.model.frame_report("sim", cfg, kept, true).delay.total_s();
        let follow_s = (first_s - self.model.weight_stream_delay_s(cfg, kept, true)).max(0.0);
        StagePair { first_s, follow_s }
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn load(&mut self, artifact: &str) -> Result<()> {
        self.inner.load(artifact)?;
        match super::host::parse_artifact(artifact)? {
            ArtifactSpec::Mgnet { image_size } => {
                self.mgnet.get_or_insert(MgnetConfig::classification(image_size));
            }
            ArtifactSpec::Backbone { variant, image_size, .. } => {
                let classes = self.inner.config().num_classes;
                self.backbone.get_or_insert(VitConfig::variant(variant, image_size, classes));
            }
        }
        Ok(())
    }

    fn is_loaded(&self, artifact: &str) -> bool {
        self.inner.is_loaded(artifact)
    }

    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
        if !self.inner.is_loaded(artifact) {
            // Route implicit loads through `Self::load` so the config
            // capture above cannot be bypassed.
            self.load(artifact)?;
        }
        self.inner.execute(artifact, inputs)
    }

    fn execute_batch(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        if !self.inner.is_loaded(artifact) {
            self.load(artifact)?;
        }
        self.inner.execute_batch(artifact, batch)
    }

    fn modeled_stages_s(
        &mut self,
        kept_patches: usize,
        use_mask: bool,
        first_in_batch: bool,
    ) -> Option<ModeledStages> {
        let vit = self.backbone?;
        if !use_mask {
            if self.full_latency.is_none() {
                self.full_latency = Some(self.stage_pair(&vit, vit.num_patches()));
            }
            let full = self.full_latency.unwrap();
            return Some(ModeledStages { mgnet_s: 0.0, backbone_s: full.pick(first_in_batch) });
        }
        let mg = self.mgnet?;
        if self.mgnet_latency.is_none() {
            let mg_vit = mg.as_vit();
            self.mgnet_latency =
                Some(self.model.frame_report("sim", &mg_vit, mg_vit.num_patches(), true).delay.total_s());
        }
        let kept = kept_patches.clamp(1, vit.num_patches());
        if self.masked_latency.len() <= kept {
            self.masked_latency.resize(kept + 1, None);
        }
        if self.masked_latency[kept].is_none() {
            self.masked_latency[kept] = Some(self.stage_pair(&vit, kept));
        }
        let backbone = self.masked_latency[kept].unwrap();
        Some(ModeledStages {
            mgnet_s: self.mgnet_latency.unwrap(),
            backbone_s: backbone.pick(first_in_batch),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vit::VitVariant;

    fn sim() -> SimBackend {
        SimBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() })
    }

    fn loaded_sim() -> SimBackend {
        let mut s = sim();
        s.load("mgnet_32").unwrap();
        s.load("vit_tiny_32_n4").unwrap();
        s
    }

    #[test]
    fn no_latency_before_any_backbone_loads() {
        let mut s = sim();
        assert_eq!(s.modeled_frame_latency_s(4, true), None);
        assert!(s.modeled_stages_s(4, true, true).is_none());
        assert_eq!(s.name(), "sim");
        assert!(!s.needs_artifacts());
    }

    #[test]
    fn modeled_latency_matches_architecture_model() {
        let mut s = loaded_sim();
        let vit = VitConfig::variant(VitVariant::Tiny, 32, 10);
        let mg = MgnetConfig::classification(32).as_vit();
        let model = AcceleratorModel::default();
        let stages = s.modeled_stages_s(2, true, true).expect("masked stages");
        // Per-stage figures come straight from the Fig. 9/11 delay model:
        // MGNet always sees the full grid, the backbone the kept count.
        let mg_expect = model.frame_report("x", &mg, mg.num_patches(), true).delay.total_s();
        let bb_expect = model.frame_report("x", &vit, 2, true).delay.total_s();
        assert_eq!(stages.mgnet_s, mg_expect);
        assert_eq!(stages.backbone_s, bb_expect);
        assert_eq!(s.modeled_frame_latency_s(2, true), Some(stages.total_s()));
        // Cached second query returns the identical value.
        assert_eq!(s.modeled_stages_s(2, true, true).unwrap().total_s(), stages.total_s());
        // Unmasked runs model the full grid with no MGNet stage.
        let full = s.modeled_stages_s(4, false, true).expect("full stages");
        assert_eq!(full.mgnet_s, 0.0);
        assert_eq!(
            full.backbone_s,
            model.frame_report("x", &vit, vit.num_patches(), true).delay.total_s()
        );
        assert!(stages.total_s() > 0.0 && full.total_s() > 0.0);
    }

    #[test]
    fn latency_grows_with_kept_patches() {
        let mut s = loaded_sim();
        let l1 = s.modeled_frame_latency_s(1, true).unwrap();
        let l4 = s.modeled_frame_latency_s(4, true).unwrap();
        assert!(l4 > l1, "more kept patches must model more latency ({l1} !< {l4})");
        // Out-of-range kept counts clamp instead of panicking.
        assert_eq!(s.modeled_frame_latency_s(0, true), Some(l1));
        assert_eq!(s.modeled_frame_latency_s(99, true), Some(l4));
    }

    #[test]
    fn batch_followers_amortize_backbone_weight_programming() {
        let mut s = loaded_sim();
        let model = AcceleratorModel::default();
        let vit = VitConfig::variant(VitVariant::Tiny, 32, 10);
        let first = s.modeled_stages_s(2, true, true).expect("first");
        let follow = s.modeled_stages_s(2, true, false).expect("follower");
        // Followers in a bucket-major batch skip the *backbone* weight
        // streaming; the MGNet stage runs per frame (interleaved with
        // other buckets) so it never amortizes.
        assert_eq!(follow.mgnet_s, first.mgnet_s, "MGNet stage must not amortize");
        assert!(follow.backbone_s < first.backbone_s, "{follow:?} !< {first:?}");
        assert!(follow.total_s() > 0.0);
        let expect_saving = model.weight_stream_delay_s(&vit, 2, true);
        let saving = first.total_s() - follow.total_s();
        assert!(
            (saving - expect_saving).abs() <= expect_saving * 1e-9,
            "saving {saving} != backbone weight-stream share {expect_saving}"
        );
        // Unmasked followers amortize too.
        let full_first = s.modeled_stages_s(4, false, true).unwrap();
        let full_follow = s.modeled_stages_s(4, false, false).unwrap();
        assert!(full_follow.backbone_s < full_first.backbone_s);
    }

    #[test]
    fn execution_delegates_to_host_numerics() {
        const PD: usize = 16 * 16 * 3;
        let x: Vec<f32> = (0..4 * PD).map(|i| (i % 13) as f32 / 13.0).collect();
        let dims = [4i64, PD as i64];
        let mut s = sim();
        let mut h = HostBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() });
        let scores_sim = s.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        let scores_host = h.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        assert_eq!(scores_sim, scores_host, "sim must reuse the host reference numerics");
        // The batched entry also routes through the host backend (and the
        // implicit-load config capture), bitwise-equal to sequential.
        let ins = [TensorRef::new(&x, &dims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&ins, &ins];
        let mut s2 = sim();
        let batched = s2.execute_batch("mgnet_32", &batch).unwrap();
        assert_eq!(batched[0][0], scores_host);
        assert_eq!(batched[1][0], scores_host);
    }
}
