//! Analytic photonic-simulation backend: [`HostBackend`] numerics, with
//! per-frame latency charged from the accelerator architecture model
//! instead of host wall-clock.
//!
//! This is the execution substrate the paper's evaluation actually reports:
//! the Fig. 9/11 delay model ([`crate::arch`] schedule + component
//! constants) decides how long a frame takes on the five-core photonic
//! accelerator, while the host merely computes the reference numerics. A
//! `--backend sim` serving run therefore produces a `ServeReport` whose
//! latency column is photonic-core time (energy was always modeled, for
//! every backend), making near-sensor operating points comparable across
//! machines regardless of host speed.
//!
//! Latency is reported **per stage** ([`ModeledStages`]): the MGNet front
//! end and the backbone are separate passes on the accelerator, and the
//! serving metrics record them under separate `"modeled_mgnet"` /
//! `"modeled_backbone"` stages. The model is also **batch-aware**: a frame
//! that rides a bucket-major batch behind its group's first frame reuses
//! the already-programmed **backbone** MR weight banks, so its backbone
//! stage drops by the weight-streaming share
//! ([`crate::energy::AcceleratorModel::weight_stream_delay_s`]) — modeled
//! time per frame *decreases* with batch size, which is the
//! dispatch-amortization effect batched photonic execution exists for.
//! The MGNet stage is never discounted: MGNet runs per frame at route
//! time, interleaved with other buckets' batches, so its banks are
//! reprogrammed regardless of batching.
//!
//! Modeled **service** figures are cached per kept-patch count: the delay
//! schedule is orders of magnitude more expensive than the energy model
//! (see `AcceleratorModel::frame_energy`), so it must never run per frame
//! — and caching *service* is sound, because it depends only on the kept
//! count and the first/follower position. What is never cached is total
//! latency: with the [`crate::cosim`] queueing co-simulation armed
//! ([`SimBackend::enable_queueing`]), every frame adds a waiting term
//! computed from its arrival against the live per-core queue state, so
//! different batch widths and offered loads genuinely report different
//! modeled latency. (The pre-co-sim cache keyed *total* latency by kept
//! count alone, silently reusing batch-amortized timings across batch
//! widths; re-keying it as a service-only cache fixed that.)

use std::time::Instant;

use anyhow::Result;

use super::host::{ArtifactSpec, HostBackend, HostConfig};
use super::{Backend, BackendHealth, ModeledStages, RecalCost, TensorRef};
use crate::arch::CoreParams;
use crate::coordinator::clock::Clock;
use crate::cosim::QueueSim;
use crate::energy::AcceleratorModel;
use crate::photonics::{DegradationState, FaultSchedule};
use crate::quant::PrecisionTier;
use crate::util::rng::Rng;
use crate::vit::{MgnetConfig, VitConfig, VitVariant};

/// `(first_in_batch, follower)` modeled **service**-latency pair for one
/// stage — load-independent by construction; queueing is never part of it.
#[derive(Debug, Clone, Copy)]
struct StagePair {
    first_s: f64,
    follow_s: f64,
}

impl StagePair {
    fn pick(&self, first_in_batch: bool) -> f64 {
        if first_in_batch {
            self.first_s
        } else {
            self.follow_s
        }
    }
}

/// Clock-driven degraded-optics state for one worker's backend: a pure
/// seeded [`FaultSchedule`] evaluated at "seconds since the last
/// recalibration epoch". Deterministic under `ManualClock` — same schedule
/// plus same advances produce bit-identical degradation and perturbations.
#[derive(Debug)]
struct WorkerFaultState {
    schedule: FaultSchedule,
    clock: Clock,
    /// Degradation accumulates from here; [`SimBackend::recalibrate`]
    /// resets it to "now".
    epoch: Instant,
}

impl WorkerFaultState {
    fn state(&self) -> DegradationState {
        self.schedule.state_at(self.clock.seconds_since(self.epoch))
    }
}

/// Armed queueing co-simulation for one worker's backend: a
/// [`QueueSim`] over the backbone's mapped task graphs, fed one arrival
/// event per modeled frame (see [`SimBackend::enable_queueing`]).
#[derive(Debug)]
struct QueueingState {
    /// Modeled optical core count (≥ 5).
    cores: usize,
    /// `Some(fps)` = paced virtual arrivals at `k / fps`; `None` = stamp
    /// arrivals from `clock`.
    pace_fps: Option<f64>,
    clock: Clock,
    /// Clock-stamped arrivals are measured from here (arming time).
    origin: Instant,
    /// Frames fed so far (the paced-arrival index).
    arrivals: u64,
    /// Built lazily on the first modeled frame — the co-sim needs the
    /// backbone config captured at artifact-load time.
    sim: Option<QueueSim>,
}

/// Latency penalty per unit of lost health: a degraded bank needs extra
/// tuning passes and guard time, up to +10% at health 0.
const FAULT_LATENCY_PENALTY: f64 = 0.10;
/// Modeled-energy penalty per unit of lost health: drift compensation and
/// re-tune retries, up to +25% at health 0 (see `Pipeline`'s accounting).
pub const FAULT_ENERGY_PENALTY: f64 = 0.25;

/// [`Backend`] that wraps [`HostBackend`] for execution and overlays
/// modeled photonic frame latency.
#[derive(Debug)]
pub struct SimBackend {
    inner: HostBackend,
    model: AcceleratorModel,
    /// Backbone/MGNet configs, captured from the artifact names at load
    /// time (the first loaded backbone defines the operating point).
    backbone: Option<VitConfig>,
    mgnet: Option<MgnetConfig>,
    /// Modeled MGNet front-end **service** latency (full grid; masked path
    /// only). Batch-independent: MGNet executes per frame at route time.
    mgnet_service: Option<f64>,
    /// Modeled masked backbone **service** latency, one lane per
    /// [`PrecisionTier`] (outer index = `tier.index()`), by kept-patch
    /// count (inner index = kept). Service only — sound to cache; total
    /// latency adds uncacheable queueing when the co-sim is armed. Tiers
    /// differ only in the batch-leader weight-streaming share: fewer
    /// converter bits stream fewer MR-programming bytes.
    masked_service: [Vec<Option<StagePair>>; 3],
    /// Modeled unmasked full-grid **service** latency, per tier.
    full_service: [Option<StagePair>; 3],
    /// Degraded-optics simulation; `None` = ideal hardware (the default,
    /// and the mode every pre-existing modeled-latency equality holds in).
    faults: Option<WorkerFaultState>,
    /// Queueing co-simulation; `None` = contention-free modeling (the
    /// default: queueing reports exactly 0, totals equal service).
    queueing: Option<QueueingState>,
}

impl SimBackend {
    pub fn new(host: HostConfig) -> Self {
        Self::with_model(host, AcceleratorModel::default())
    }

    pub fn with_model(host: HostConfig, model: AcceleratorModel) -> Self {
        SimBackend {
            inner: HostBackend::new(host),
            model,
            backbone: None,
            mgnet: None,
            mgnet_service: None,
            masked_service: [Vec::new(), Vec::new(), Vec::new()],
            full_service: [None; 3],
            faults: None,
            queueing: None,
        }
    }

    /// The architecture model charging the latency.
    pub fn model(&self) -> &AcceleratorModel {
        &self.model
    }

    /// Enable clock-driven degraded-optics simulation: `schedule` is
    /// evaluated at seconds-of-`clock`-time since construction (or since
    /// the last [`Backend::recalibrate`]). Outputs gain seeded pseudo-noise
    /// at the schedule's estimated RMS weight error, and modeled latency
    /// inflates by up to [`FAULT_LATENCY_PENALTY`] as health decays.
    pub fn enable_faults(&mut self, schedule: FaultSchedule, clock: Clock) {
        let epoch = clock.now();
        self.faults = Some(WorkerFaultState { schedule, clock, epoch });
    }

    /// Arm the scheduler queueing co-simulation ([`crate::cosim`]):
    /// modeled latency gains a load-dependent waiting stage, fed one
    /// arrival event per frame. `cores` is the modeled optical core count
    /// (≥ 5 — the Fig. 5 flow needs five). `pace_fps = Some(f)` paces
    /// deterministic virtual arrivals at `f` frames/s (the offered-load
    /// knob for operating-point studies); `None` stamps arrivals from
    /// `clock` as frames reach the backend — the actual serving arrival
    /// process, exact under `ManualClock`. Cached service figures stay
    /// pristine; queueing is computed per arrival and never cached.
    pub fn enable_queueing(&mut self, cores: usize, pace_fps: Option<f64>, clock: Clock) {
        assert!(cores >= 5, "the Fig. 5 flow needs at least 5 cores (got {cores})");
        let origin = clock.now();
        self.queueing =
            Some(QueueingState { cores, pace_fps, clock, origin, arrivals: 0, sim: None });
    }

    /// Current degradation, if fault simulation is enabled.
    fn degradation(&self) -> Option<DegradationState> {
        self.faults.as_ref().map(WorkerFaultState::state)
    }

    /// Modeled-latency inflation factor at the current degradation level
    /// (1.0 on ideal hardware, so cached pristine figures pass through
    /// untouched).
    fn latency_factor(&self) -> f64 {
        match self.degradation() {
            Some(d) => 1.0 + FAULT_LATENCY_PENALTY * (1.0 - d.health()),
            None => 1.0,
        }
    }

    /// Perturb host-computed outputs with seeded pseudo-noise at the
    /// degradation's estimated RMS weight error. The noise generator is
    /// seeded from the schedule seed and the *quantized* degradation
    /// state, so identical clock timelines perturb identically and the
    /// pristine state (rms 0) is a no-op.
    fn perturb(&self, outputs: &mut [Vec<f32>]) {
        let Some(fs) = &self.faults else { return };
        let d = fs.state();
        let rms = d.estimated_rms_error();
        if rms <= 0.0 {
            return;
        }
        // Quantize the error level so the seed is stable across f64 jitter
        // (1e-6 steps of rms; ManualClock timelines land on exact steps).
        let level = (rms * 1e6).round() as u64;
        let mut rng = Rng::new(fs.schedule.seed ^ level.rotate_left(17));
        for out in outputs.iter_mut() {
            for x in out.iter_mut() {
                *x += (rms * rng.uniform(-1.0, 1.0)) as f32;
            }
        }
    }

    /// Model one pass of `cfg` at `kept` patches and `tier`: full latency
    /// for a batch-first frame, and the follower latency with the
    /// weight-stream share amortized away. The baseline delay schedule is
    /// tier-independent (symbol rate is set by the optics, not the
    /// converter width); only the leader's MR weight-streaming share
    /// scales with the tier's bits. At INT8 the substitution
    /// `base + (ws_tier - ws_int8)` adds exactly `0.0`, so the INT8 pair
    /// is bit-identical to the historical untiered figures.
    fn stage_pair(&self, cfg: &VitConfig, kept: usize, tier: PrecisionTier) -> StagePair {
        let base_s = self.model.frame_report("sim", cfg, kept, true).delay.total_s();
        let ws_int8 = self.model.weight_stream_delay_s(cfg, kept, true);
        let ws_tier = self.model.weight_stream_delay_s_tiered(cfg, kept, true, tier);
        StagePair {
            first_s: (base_s + (ws_tier - ws_int8)).max(0.0),
            follow_s: (base_s - ws_int8).max(0.0),
        }
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn load(&mut self, artifact: &str) -> Result<()> {
        self.inner.load(artifact)?;
        match super::host::parse_artifact(artifact)? {
            ArtifactSpec::Mgnet { image_size } => {
                self.mgnet.get_or_insert(MgnetConfig::classification(image_size));
            }
            ArtifactSpec::Backbone { variant, image_size, .. } => {
                let classes = self.inner.config().num_classes;
                self.backbone.get_or_insert(VitConfig::variant(variant, image_size, classes));
            }
        }
        Ok(())
    }

    fn is_loaded(&self, artifact: &str) -> bool {
        self.inner.is_loaded(artifact)
    }

    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
        if !self.inner.is_loaded(artifact) {
            // Route implicit loads through `Self::load` so the config
            // capture above cannot be bypassed.
            self.load(artifact)?;
        }
        let mut out = self.inner.execute(artifact, inputs)?;
        self.perturb(&mut out);
        Ok(out)
    }

    fn execute_batch(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        self.execute_batch_tiered(artifact, batch, PrecisionTier::Int8)
    }

    /// Tiered execution routes to the host backend's per-tier quantized
    /// modules; fault perturbation applies on top regardless of tier (MR
    /// drift afflicts the optics, not the converters).
    fn execute_batch_tiered(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
        tier: PrecisionTier,
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        if !self.inner.is_loaded(artifact) {
            self.load(artifact)?;
        }
        let mut out = self.inner.execute_batch_tiered(artifact, batch, tier)?;
        for frame in out.iter_mut() {
            self.perturb(frame);
        }
        Ok(out)
    }

    fn modeled_stages_s(
        &mut self,
        kept_patches: usize,
        use_mask: bool,
        first_in_batch: bool,
    ) -> Option<ModeledStages> {
        self.modeled_stages_s_tiered(kept_patches, use_mask, first_in_batch, PrecisionTier::Int8)
    }

    fn modeled_stages_s_tiered(
        &mut self,
        kept_patches: usize,
        use_mask: bool,
        first_in_batch: bool,
        tier: PrecisionTier,
    ) -> Option<ModeledStages> {
        let vit = self.backbone?;
        // Caches hold pristine-hardware figures; degradation inflates them
        // at return time so recalibration instantly restores the ideal
        // model (factor 1.0 when fault simulation is off).
        let k = self.latency_factor();
        let ti = tier.index();
        if !use_mask {
            if self.full_service[ti].is_none() {
                self.full_service[ti] = Some(self.stage_pair(&vit, vit.num_patches(), tier));
            }
            let full = self.full_service[ti].unwrap();
            return Some(ModeledStages {
                mgnet_s: 0.0,
                backbone_s: full.pick(first_in_batch) * k,
                queueing_s: 0.0,
            });
        }
        let mg = self.mgnet?;
        if self.mgnet_service.is_none() {
            // The MGNet front end always runs at INT8 (mask quality gates
            // everything downstream), so its service figure is tierless.
            let mg_vit = mg.as_vit();
            self.mgnet_service =
                Some(self.model.frame_report("sim", &mg_vit, mg_vit.num_patches(), true).delay.total_s());
        }
        let kept = kept_patches.clamp(1, vit.num_patches());
        if self.masked_service[ti].len() <= kept {
            self.masked_service[ti].resize(kept + 1, None);
        }
        if self.masked_service[ti][kept].is_none() {
            self.masked_service[ti][kept] = Some(self.stage_pair(&vit, kept, tier));
        }
        let backbone = self.masked_service[ti][kept].unwrap();
        Some(ModeledStages {
            mgnet_s: self.mgnet_service.unwrap() * k,
            backbone_s: backbone.pick(first_in_batch) * k,
            queueing_s: 0.0,
        })
    }

    fn modeled_queueing_s(&mut self, kept_patches: usize, use_mask: bool) -> f64 {
        // Degradation inflates waiting exactly like it inflates service
        // (read the factor before mutably holding the queueing state).
        let k = self.latency_factor();
        let Some(vit) = self.backbone else { return 0.0 };
        let Some(q) = self.queueing.as_mut() else { return 0.0 };
        let n_tokens =
            if use_mask { kept_patches.clamp(1, vit.num_patches()) } else { vit.num_patches() };
        let arrival_s = match q.pace_fps {
            Some(fps) => q.arrivals as f64 / fps,
            None => q.clock.seconds_since(q.origin),
        };
        q.arrivals += 1;
        let cores = q.cores;
        let sim = q.sim.get_or_insert_with(|| {
            QueueSim::new(vit, CoreParams { num_cores: cores, ..CoreParams::default() })
        });
        let span = sim.arrive(arrival_s * 1e9, n_tokens);
        span.queueing_ns * 1e-9 * k
    }

    fn health(&mut self) -> Option<BackendHealth> {
        let d = self.degradation()?;
        Some(BackendHealth {
            health: d.health(),
            drift_nm: d.drift_nm,
            stuck_cells: d.stuck_cells,
            dead_lanes: d.dead_lanes,
            at_risk: d.at_risk(),
        })
    }

    fn recalibrate(&mut self) -> Option<RecalCost> {
        // Cost first (immutable borrows), then reset the epoch.
        let cfg = self.backbone.unwrap_or_else(|| {
            VitConfig::variant(VitVariant::Tiny, 96, self.inner.config().num_classes)
        });
        let (time_s, energy_j) = self.model.recalibration_cost(&cfg);
        let fs = self.faults.as_mut()?;
        fs.epoch = fs.clock.now();
        Some(RecalCost { time_s, energy_j })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sim() -> SimBackend {
        SimBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() })
    }

    fn loaded_sim() -> SimBackend {
        let mut s = sim();
        s.load("mgnet_32").unwrap();
        s.load("vit_tiny_32_n4").unwrap();
        s
    }

    #[test]
    fn no_latency_before_any_backbone_loads() {
        let mut s = sim();
        assert_eq!(s.modeled_frame_latency_s(4, true), None);
        assert!(s.modeled_stages_s(4, true, true).is_none());
        assert_eq!(s.name(), "sim");
        assert!(!s.needs_artifacts());
    }

    #[test]
    fn modeled_latency_matches_architecture_model() {
        let mut s = loaded_sim();
        let vit = VitConfig::variant(VitVariant::Tiny, 32, 10);
        let mg = MgnetConfig::classification(32).as_vit();
        let model = AcceleratorModel::default();
        let stages = s.modeled_stages_s(2, true, true).expect("masked stages");
        // Per-stage figures come straight from the Fig. 9/11 delay model:
        // MGNet always sees the full grid, the backbone the kept count.
        let mg_expect = model.frame_report("x", &mg, mg.num_patches(), true).delay.total_s();
        let bb_expect = model.frame_report("x", &vit, 2, true).delay.total_s();
        assert_eq!(stages.mgnet_s, mg_expect);
        assert_eq!(stages.backbone_s, bb_expect);
        assert_eq!(s.modeled_frame_latency_s(2, true), Some(stages.total_s()));
        // Cached second query returns the identical value.
        assert_eq!(s.modeled_stages_s(2, true, true).unwrap().total_s(), stages.total_s());
        // Unmasked runs model the full grid with no MGNet stage.
        let full = s.modeled_stages_s(4, false, true).expect("full stages");
        assert_eq!(full.mgnet_s, 0.0);
        assert_eq!(
            full.backbone_s,
            model.frame_report("x", &vit, vit.num_patches(), true).delay.total_s()
        );
        assert!(stages.total_s() > 0.0 && full.total_s() > 0.0);
    }

    #[test]
    fn latency_grows_with_kept_patches() {
        let mut s = loaded_sim();
        let l1 = s.modeled_frame_latency_s(1, true).unwrap();
        let l4 = s.modeled_frame_latency_s(4, true).unwrap();
        assert!(l4 > l1, "more kept patches must model more latency ({l1} !< {l4})");
        // Out-of-range kept counts clamp instead of panicking.
        assert_eq!(s.modeled_frame_latency_s(0, true), Some(l1));
        assert_eq!(s.modeled_frame_latency_s(99, true), Some(l4));
    }

    #[test]
    fn batch_followers_amortize_backbone_weight_programming() {
        let mut s = loaded_sim();
        let model = AcceleratorModel::default();
        let vit = VitConfig::variant(VitVariant::Tiny, 32, 10);
        let first = s.modeled_stages_s(2, true, true).expect("first");
        let follow = s.modeled_stages_s(2, true, false).expect("follower");
        // Followers in a bucket-major batch skip the *backbone* weight
        // streaming; the MGNet stage runs per frame (interleaved with
        // other buckets) so it never amortizes.
        assert_eq!(follow.mgnet_s, first.mgnet_s, "MGNet stage must not amortize");
        assert!(follow.backbone_s < first.backbone_s, "{follow:?} !< {first:?}");
        assert!(follow.total_s() > 0.0);
        let expect_saving = model.weight_stream_delay_s(&vit, 2, true);
        let saving = first.total_s() - follow.total_s();
        assert!(
            (saving - expect_saving).abs() <= expect_saving * 1e-9,
            "saving {saving} != backbone weight-stream share {expect_saving}"
        );
        // Unmasked followers amortize too.
        let full_first = s.modeled_stages_s(4, false, true).unwrap();
        let full_follow = s.modeled_stages_s(4, false, false).unwrap();
        assert!(full_follow.backbone_s < full_first.backbone_s);
    }

    #[test]
    fn tiered_latency_scales_only_the_leader_weight_streaming() {
        let mut s = loaded_sim();
        let model = AcceleratorModel::default();
        let vit = VitConfig::variant(VitVariant::Tiny, 32, 10);
        // INT8 tier is bit-identical to the untiered modeled figures.
        let untiered = s.modeled_stages_s(2, true, true).expect("untiered");
        let int8 = s.modeled_stages_s_tiered(2, true, true, PrecisionTier::Int8).expect("int8");
        assert_eq!(untiered, int8, "INT8 tier must reuse the untiered figures bitwise");
        // INT4 leaders stream half the MR-programming bytes; fp32 four
        // times as many. Followers never pay weight streaming, so they
        // are identical at every tier.
        let int4 = s.modeled_stages_s_tiered(2, true, true, PrecisionTier::Int4).expect("int4");
        let fp32 = s.modeled_stages_s_tiered(2, true, true, PrecisionTier::Fp32).expect("fp32");
        assert!(int4.backbone_s < int8.backbone_s && int8.backbone_s < fp32.backbone_s);
        assert_eq!(int4.mgnet_s, int8.mgnet_s, "MGNet stage is tierless (always INT8)");
        let ws8 = model.weight_stream_delay_s(&vit, 2, true);
        let ws4 = model.weight_stream_delay_s_tiered(&vit, 2, true, PrecisionTier::Int4);
        let saving = int8.backbone_s - int4.backbone_s;
        assert!(
            (saving - (ws8 - ws4)).abs() <= ws8 * 1e-9,
            "INT4 saving {saving} != weight-stream delta {}",
            ws8 - ws4
        );
        for tier in PrecisionTier::ALL {
            let follow = s.modeled_stages_s_tiered(2, true, false, tier).expect("follower");
            assert_eq!(
                follow.backbone_s,
                s.modeled_stages_s(2, true, false).unwrap().backbone_s,
                "followers must model identical latency at every tier"
            );
        }
    }

    #[test]
    fn execution_delegates_to_host_numerics() {
        const PD: usize = 16 * 16 * 3;
        let x: Vec<f32> = (0..4 * PD).map(|i| (i % 13) as f32 / 13.0).collect();
        let dims = [4i64, PD as i64];
        let mut s = sim();
        let mut h = HostBackend::new(HostConfig { depth_limit: Some(1), ..HostConfig::default() });
        let scores_sim = s.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        let scores_host = h.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        assert_eq!(scores_sim, scores_host, "sim must reuse the host reference numerics");
        // The batched entry also routes through the host backend (and the
        // implicit-load config capture), bitwise-equal to sequential.
        let ins = [TensorRef::new(&x, &dims)];
        let batch: Vec<&[TensorRef<'_>]> = vec![&ins, &ins];
        let mut s2 = sim();
        let batched = s2.execute_batch("mgnet_32", &batch).unwrap();
        assert_eq!(batched[0][0], scores_host);
        assert_eq!(batched[1][0], scores_host);
    }

    #[test]
    fn no_fault_state_means_no_health() {
        let mut s = loaded_sim();
        assert_eq!(s.health(), None);
        assert_eq!(s.recalibrate(), None);
    }

    #[test]
    fn fault_schedule_degrades_and_recal_restores() {
        let (clock, manual) = Clock::manual();
        let mut s = loaded_sim();
        // Seed 5: stuck onset at ~56 s, dead lanes at ~402/541 s.
        s.enable_faults(FaultSchedule::seeded_for_bank(5, 1e-3, 32, 64), clock);
        let h0 = s.health().expect("fault sim enabled");
        assert_eq!(h0.health, 1.0);
        assert!(!h0.at_risk);
        let base = s.modeled_frame_latency_s(2, true).unwrap();

        manual.advance(Duration::from_secs(200));
        let h1 = s.health().unwrap();
        assert!(h1.health < 1.0, "{h1:?}");
        assert!(h1.drift_nm > 0.0 && h1.stuck_cells >= 1);
        let degraded = s.modeled_frame_latency_s(2, true).unwrap();
        assert!(degraded > base, "degraded latency {degraded} !> {base}");

        let cost = s.recalibrate().expect("recal on fault sim");
        assert!(cost.time_s > 0.0 && cost.energy_j > 0.0);
        let h2 = s.health().unwrap();
        assert_eq!(h2.health, 1.0, "recal must restore pristine optics");
        assert_eq!(h2.drift_nm, 0.0);
        // Pristine caches were never poisoned: the ideal figure returns.
        assert_eq!(s.modeled_frame_latency_s(2, true), Some(base));
    }

    #[test]
    fn degraded_outputs_are_perturbed_but_deterministic() {
        const PD: usize = 16 * 16 * 3;
        let x: Vec<f32> = (0..4 * PD).map(|i| (i % 13) as f32 / 13.0).collect();
        let dims = [4i64, PD as i64];
        let run = || {
            let (clock, manual) = Clock::manual();
            let mut s = loaded_sim();
            s.enable_faults(FaultSchedule::seeded_for_bank(9, 5e-4, 32, 64), clock);
            manual.advance(Duration::from_secs(150));
            let out = s.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
            (s.health().unwrap(), out)
        };
        let (ha, oa) = run();
        let (hb, ob) = run();
        assert_eq!(ha, hb, "same seed + same manual timeline → same health");
        assert_eq!(oa, ob, "→ bit-identical perturbed outputs");
        // And the perturbation is real: clean numerics differ.
        let mut clean = loaded_sim();
        let oc = clean.execute1("mgnet_32", &[TensorRef::new(&x, &dims)]).unwrap();
        assert_ne!(oa, oc, "degraded outputs must deviate from ideal numerics");
    }

    #[test]
    fn queueing_off_reports_exactly_zero_waiting() {
        let mut s = loaded_sim();
        assert_eq!(s.modeled_queueing_s(2, true), 0.0);
        let stages = s.modeled_stages_s(2, true, true).unwrap();
        assert_eq!(stages.queueing_s, 0.0);
        assert_eq!(stages.total_s(), stages.mgnet_s + stages.backbone_s);
    }

    #[test]
    fn batch_width_changes_modeled_latency() {
        // Regression for the old per-kept-count *total*-latency cache,
        // which reported identical modeled latency for every batch width.
        // With the co-sim armed, a frozen ManualClock stamps a whole batch
        // at the same arrival instant: followers queue behind the first
        // frame, so mean modeled latency strictly grows with batch width.
        let mean_total = |width: usize| {
            let (clock, _manual) = Clock::manual();
            let mut s = loaded_sim();
            s.enable_queueing(5, None, clock);
            let mut sum = 0.0;
            for i in 0..width {
                let stages = s.modeled_stages_s(2, true, i == 0).unwrap();
                sum += stages.total_s() + s.modeled_queueing_s(2, true);
            }
            sum / width as f64
        };
        let w1 = mean_total(1);
        let w4 = mean_total(4);
        assert!(w4 > w1, "batch width must change modeled latency: {w4} !> {w1}");
        assert_eq!(mean_total(4), w4, "co-sim replay must be deterministic");
    }

    #[test]
    fn paced_queueing_is_deterministic_and_load_sensitive() {
        let run = |fps: f64| {
            let (clock, _manual) = Clock::manual();
            let mut s = loaded_sim();
            s.enable_queueing(5, Some(fps), clock);
            (0..6).map(|_| s.modeled_queueing_s(4, true)).collect::<Vec<f64>>()
        };
        // 100 fps = 10 ms gaps: orders of magnitude beyond the modeled
        // service time, so every frame lands on idle hardware.
        let sparse = run(100.0);
        assert!(sparse.iter().all(|&q| q == 0.0), "sparse arrivals must not queue: {sparse:?}");
        // 1e9 fps = 1 ns gaps: effectively simultaneous, so every frame
        // after the first waits.
        let dense = run(1e9);
        assert!(
            dense.iter().skip(1).all(|&q| q > 0.0),
            "near-simultaneous arrivals must queue: {dense:?}"
        );
        assert_eq!(dense[0], 0.0, "the first frame arrives to an idle accelerator");
        assert_eq!(dense, run(1e9), "same pace → bitwise-identical queueing");
    }
}
