//! PJRT execution backend: loads the AOT-compiled HLO-text artifacts
//! produced by `python/compile/aot.py` and executes them on the CPU PJRT
//! client.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto` —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`): a [`PjrtBackend`] must be
//! created and used on a single thread. The coordinator constructs one
//! inside each worker thread (see [`crate::coordinator::engine`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{AsTensorRef, Backend, TensorRef};

/// PJRT-backed executor over a directory of `*.hlo.txt` artifacts — the
/// production implementation of [`Backend`].
pub struct PjrtBackend {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    /// Create a CPU-PJRT backend rooted at `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            executables: HashMap::new(),
        })
    }

    /// Artifact names available on disk (file stems of `*.hlo.txt`).
    pub fn available(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.artifact_dir) {
            for e in rd.flatten() {
                let p = e.path();
                if let Some(name) = p.file_name().and_then(|s| s.to_str()) {
                    if let Some(stem) = name.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }

    /// Load + compile an artifact (cached). Compilation happens once per
    /// name per process — never on the steady-state request path.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact '{}' not found at {} — run `make artifacts` first",
                name,
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).with_context(|| format!("compiling artifact '{name}'"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` with the given inputs (owned [`super::Tensor`]s
    /// or borrowed [`TensorRef`]s); returns all tuple outputs as flat f32
    /// vectors (artifacts are lowered with `return_tuple=True`).
    pub fn execute<T: AsTensorRef>(&mut self, name: &str, inputs: &[T]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let exe = self.executables.get(name).expect("just loaded");
        let literals = stage_literals(inputs)?;
        run_executable(exe, name, &literals)
    }

    /// Convenience: execute and return the single output.
    pub fn execute1<T: AsTensorRef>(&mut self, name: &str, inputs: &[T]) -> Result<Vec<f32>> {
        let mut outs = self.execute(name, inputs)?;
        if outs.len() != 1 {
            bail!("artifact '{name}' returned {} outputs, expected 1", outs.len());
        }
        Ok(outs.pop().unwrap())
    }
}

/// Convert one frame's inputs into device literals — shared by the
/// per-frame and batched entry points so their input handling can never
/// diverge.
fn stage_literals<T: AsTensorRef>(inputs: &[T]) -> Result<Vec<xla::Literal>> {
    let mut literals = Vec::with_capacity(inputs.len());
    for t in inputs {
        let t = t.tensor_ref();
        let lit = xla::Literal::vec1(t.data);
        let lit = if t.dims.is_empty() {
            lit
        } else {
            lit.reshape(t.dims).with_context(|| format!("reshaping input to {:?}", t.dims))?
        };
        literals.push(lit);
    }
    Ok(literals)
}

/// Drive one compiled executable and unpack its tuple outputs — shared by
/// the per-frame and batched entry points.
fn run_executable(
    exe: &xla::PjRtLoadedExecutable,
    name: &str,
    literals: &[xla::Literal],
) -> Result<Vec<Vec<f32>>> {
    let result = exe
        .execute::<xla::Literal>(literals)
        .with_context(|| format!("executing artifact '{name}'"))?[0][0]
        .to_literal_sync()?;
    let parts = result.to_tuple().context("artifact output is not a tuple")?;
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        out.push(p.to_vec::<f32>().context("non-f32 artifact output")?);
    }
    Ok(out)
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn needs_artifacts(&self) -> bool {
        true
    }

    fn load(&mut self, artifact: &str) -> Result<()> {
        PjrtBackend::load(self, artifact)
    }

    fn is_loaded(&self, artifact: &str) -> bool {
        PjrtBackend::is_loaded(self, artifact)
    }

    fn execute(&mut self, artifact: &str, inputs: &[TensorRef<'_>]) -> Result<Vec<Vec<f32>>> {
        // Resolves to the inherent generic `execute` (inherent methods take
        // precedence over trait methods), instantiated at `T = TensorRef`.
        PjrtBackend::execute(self, artifact, inputs)
    }

    /// Native batched dispatch: the artifact is resolved and compiled
    /// **once** per batch, then the cached executable is driven
    /// back-to-back over every frame with no per-frame artifact lookup.
    /// The compiled HLO ABI is fixed-shape — bucket artifacts carry no
    /// leading batch dimension — so what amortizes here is the dispatch
    /// overhead around each run (resolution, cache lookup), which the
    /// per-frame `execute` path pays on every call. Staging and unpacking
    /// share `stage_literals`/`run_executable` with the per-frame path,
    /// so the two can never diverge numerically.
    fn execute_batch(
        &mut self,
        artifact: &str,
        batch: &[&[TensorRef<'_>]],
    ) -> Result<Vec<Vec<Vec<f32>>>> {
        PjrtBackend::load(self, artifact)?;
        let exe = self.executables.get(artifact).expect("just loaded");
        let mut out = Vec::with_capacity(batch.len());
        for inputs in batch {
            let literals = stage_literals(inputs)?;
            out.push(run_executable(exe, artifact, &literals)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    #[test]
    fn missing_artifact_is_error() {
        let mut rt = PjrtBackend::new("/nonexistent-artifacts").unwrap();
        let err = rt.execute::<Tensor>("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn available_lists_hlo_files() {
        let dir = std::env::temp_dir().join("optovit-rt-test");
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("b.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("c.other"), "x").unwrap();
        let rt = PjrtBackend::new(&dir).unwrap();
        assert_eq!(rt.available(), vec!["a".to_string(), "b".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trait_object_surface_matches_inherent() {
        let mut rt = PjrtBackend::new("/nonexistent-artifacts").unwrap();
        let b: &mut dyn Backend = &mut rt;
        assert_eq!(b.name(), "pjrt");
        assert!(b.needs_artifacts());
        assert!(!b.is_loaded("nope"));
        assert!(b.load("nope").is_err());
        // Latency is measured, not modeled, on the real substrate.
        assert_eq!(b.modeled_frame_latency_s(10, true), None);
        assert!(b.modeled_stages_s(10, true, false).is_none());
        // The batched entry resolves the artifact first, so a missing
        // artifact fails before any literal staging.
        let err = b.execute_batch("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
