//! ViT-Tiny/Small/Base/Large hyperparameters (Table I / Figs. 8-11 grid)
//! and the MGNet mask-generator configuration (§IV).

use std::fmt;

/// The four backbone scales evaluated in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VitVariant {
    Tiny,
    Small,
    Base,
    Large,
}

impl VitVariant {
    pub const ALL: [VitVariant; 4] =
        [VitVariant::Tiny, VitVariant::Small, VitVariant::Base, VitVariant::Large];

    pub fn name(&self) -> &'static str {
        match self {
            VitVariant::Tiny => "Tiny",
            VitVariant::Small => "Small",
            VitVariant::Base => "Base",
            VitVariant::Large => "Large",
        }
    }

    /// Inverse of `name()` (case-insensitive) — used to parse the variant
    /// segment of artifact names like `vit_tiny_96_n36`.
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" => Some(VitVariant::Tiny),
            "small" => Some(VitVariant::Small),
            "base" => Some(VitVariant::Base),
            "large" => Some(VitVariant::Large),
            _ => None,
        }
    }
}

impl fmt::Display for VitVariant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Full hyperparameter set for one ViT instantiation on one input size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VitConfig {
    /// Input image side (images are square): 96 or 224 in the paper.
    pub image_size: usize,
    /// Patch side `p` (16 throughout the paper).
    pub patch_size: usize,
    /// Embedding dimension `d_m`.
    pub embed_dim: usize,
    /// Number of attention heads `h`.
    pub num_heads: usize,
    /// Encoder depth `L`.
    pub depth: usize,
    /// FFN expansion ratio (4 for all standard ViTs).
    pub mlp_ratio: usize,
    /// Classifier output dimension.
    pub num_classes: usize,
}

impl VitConfig {
    /// Standard variant hyperparameters (Dosovitskiy et al.).
    pub fn variant(v: VitVariant, image_size: usize, num_classes: usize) -> Self {
        let (embed_dim, num_heads, depth) = match v {
            VitVariant::Tiny => (192, 3, 12),
            VitVariant::Small => (384, 6, 12),
            VitVariant::Base => (768, 12, 12),
            VitVariant::Large => (1024, 16, 24),
        };
        VitConfig {
            image_size,
            patch_size: 16,
            embed_dim,
            num_heads,
            depth,
            mlp_ratio: 4,
            num_classes,
        }
    }

    /// Patches per side.
    pub fn patches_per_side(&self) -> usize {
        assert_eq!(
            self.image_size % self.patch_size,
            0,
            "image size {} not divisible by patch size {}",
            self.image_size,
            self.patch_size
        );
        self.image_size / self.patch_size
    }

    /// Total patch count `n` (excluding the cls token).
    pub fn num_patches(&self) -> usize {
        let s = self.patches_per_side();
        s * s
    }

    /// Sequence length including the cls token.
    pub fn seq_len(&self) -> usize {
        self.num_patches() + 1
    }

    /// Per-head dimension `d_k = d_m / h` — 64 for every standard variant,
    /// matching the 64 arms of the optical core (§III).
    pub fn head_dim(&self) -> usize {
        assert_eq!(self.embed_dim % self.num_heads, 0);
        self.embed_dim / self.num_heads
    }

    /// Flattened patch input dimension `p*p*3`.
    pub fn patch_dim(&self) -> usize {
        self.patch_size * self.patch_size * 3
    }

    /// FFN hidden dimension.
    pub fn ffn_dim(&self) -> usize {
        self.embed_dim * self.mlp_ratio
    }

    /// Total parameter count (weights + biases, embeddings, head).
    pub fn param_count(&self) -> usize {
        let d = self.embed_dim;
        let f = self.ffn_dim();
        let embed = self.patch_dim() * d + d; // patch projection
        let pos = self.seq_len() * d + d; // positional + cls token
        let per_block = {
            let qkv = 3 * (d * d + d);
            let proj = d * d + d;
            let ffn = d * f + f + f * d + d;
            let norms = 4 * d;
            qkv + proj + ffn + norms
        };
        let head = d * self.num_classes + self.num_classes;
        embed + pos + self.depth * per_block + head + 2 * d /* final norm */
    }
}

/// MGNet configuration (§IV): a single transformer block + cls-attention
/// scorer + linear per-patch logits, thresholded into a binary mask.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MgnetConfig {
    pub image_size: usize,
    pub patch_size: usize,
    pub embed_dim: usize,
    pub num_heads: usize,
    /// Sigmoid threshold `t_reg` for the binary mask.
    pub region_threshold: f64,
}

impl MgnetConfig {
    /// The classification-task MGNet: patch 16, embed 192, 3 heads.
    pub fn classification(image_size: usize) -> Self {
        MgnetConfig {
            image_size,
            patch_size: 16,
            embed_dim: 192,
            num_heads: 3,
            region_threshold: 0.5,
        }
    }

    /// The detection-task MGNet (§IV-2): embed 384, 6 heads.
    pub fn detection(image_size: usize) -> Self {
        MgnetConfig {
            image_size,
            patch_size: 16,
            embed_dim: 384,
            num_heads: 6,
            region_threshold: 0.5,
        }
    }

    pub fn num_patches(&self) -> usize {
        let s = self.image_size / self.patch_size;
        s * s
    }

    /// The MGNet is itself a one-block ViT; reuse the workload machinery.
    pub fn as_vit(&self) -> VitConfig {
        VitConfig {
            image_size: self.image_size,
            patch_size: self.patch_size,
            embed_dim: self.embed_dim,
            num_heads: self.num_heads,
            depth: 1,
            mlp_ratio: 4,
            // scoring head: one logit per patch
            num_classes: self.num_patches(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_name_roundtrip() {
        for v in VitVariant::ALL {
            assert_eq!(VitVariant::from_name(&v.name().to_lowercase()), Some(v));
            assert_eq!(VitVariant::from_name(v.name()), Some(v));
        }
        assert_eq!(VitVariant::from_name("giant"), None);
    }

    #[test]
    fn head_dim_is_64_for_all_variants() {
        for v in VitVariant::ALL {
            let c = VitConfig::variant(v, 224, 1000);
            assert_eq!(c.head_dim(), 64, "{v}: d_k must match the 64-arm core");
        }
    }

    #[test]
    fn patch_counts() {
        let c96 = VitConfig::variant(VitVariant::Tiny, 96, 10);
        assert_eq!(c96.num_patches(), 36);
        assert_eq!(c96.seq_len(), 37);
        let c224 = VitConfig::variant(VitVariant::Base, 224, 1000);
        assert_eq!(c224.num_patches(), 196);
    }

    #[test]
    fn param_counts_match_published_scale() {
        // ViT-T ~5.7M, ViT-S ~22M, ViT-B ~86M, ViT-L ~307M (ImageNet heads).
        let t = VitConfig::variant(VitVariant::Tiny, 224, 1000).param_count();
        let s = VitConfig::variant(VitVariant::Small, 224, 1000).param_count();
        let b = VitConfig::variant(VitVariant::Base, 224, 1000).param_count();
        let l = VitConfig::variant(VitVariant::Large, 224, 1000).param_count();
        assert!((5_000_000..7_000_000).contains(&t), "tiny {t}");
        assert!((20_000_000..24_000_000).contains(&s), "small {s}");
        assert!((82_000_000..90_000_000).contains(&b), "base {b}");
        assert!((295_000_000..320_000_000).contains(&l), "large {l}");
    }

    #[test]
    #[should_panic]
    fn indivisible_image_size_panics() {
        VitConfig::variant(VitVariant::Tiny, 100, 10).num_patches();
    }

    #[test]
    fn mgnet_matches_paper() {
        let m = MgnetConfig::classification(224);
        assert_eq!(m.embed_dim, 192);
        assert_eq!(m.num_heads, 3);
        assert_eq!(m.num_patches(), 196);
        let d = MgnetConfig::detection(224);
        assert_eq!(d.embed_dim, 384);
        assert_eq!(d.num_heads, 6);
        assert_eq!(d.as_vit().depth, 1);
    }
}
