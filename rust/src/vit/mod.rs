//! Vision-Transformer model configurations (the four variants the paper
//! evaluates, plus the MGNet RoI mask generator).

pub mod config;

pub use config::{MgnetConfig, VitConfig, VitVariant};
