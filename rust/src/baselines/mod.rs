//! Analytic models of competing accelerators (Table IV) and reference
//! computing platforms (§IV "Performance Comparison").
//!
//! The paper "reconstructed each design to closely match the original,
//! leveraging our evaluation framework and proprietary simulator, and
//! ensured a consistent area constraint (≈20-60 mm²)". We do the same:
//! each competitor is a structural throughput/power model whose parameters
//! come from its publication (photonic MAC count, clock, bit-serial passes
//! needed for 8-bit ViT inference, active power envelope at the common
//! area budget). The *common workload* for the FPS metric is the paper's
//! reference operating point: ViT-Tiny at 96×96 with RoI masking.

use crate::arch::workload::Workload;
use crate::energy::AcceleratorModel;
use crate::vit::{MgnetConfig, VitConfig, VitVariant};

/// Structural throughput/power model of one SiPh accelerator.
#[derive(Debug, Clone)]
pub struct SiphAccelerator {
    pub name: &'static str,
    /// CMOS interface node (nm); `None` = not reported (CrossLight).
    pub node_nm: Option<u32>,
    /// Modeled silicon area (mm²) under the common constraint.
    pub area_mm2: f64,
    /// Photonic MACs per cycle at full utilization.
    pub macs_per_cycle: f64,
    /// Compute clock (GHz) — generally the ADC sampling wall.
    pub clock_ghz: f64,
    /// Achievable utilization on ViT-style MatMuls (padding + dataflow).
    pub vit_utilization: f64,
    /// Passes needed per 8-bit MAC (binary/low-bit designs pay bit-serial
    /// repetition: LightBulb's XNOR core needs 8×8 = 64 1-bit passes, etc.).
    pub passes_for_8bit: f64,
    /// Active power (W) at that throughput, from the publication scaled to
    /// the common area budget.
    pub power_w: f64,
}

impl SiphAccelerator {
    /// Frames/s on a workload of `macs` MACs.
    pub fn fps(&self, macs: u64) -> f64 {
        let eff_macs_per_s =
            self.macs_per_cycle * self.clock_ghz * 1e9 * self.vit_utilization / self.passes_for_8bit;
        eff_macs_per_s / macs as f64
    }

    /// The Table-IV metric.
    pub fn kfps_per_watt(&self, macs: u64) -> f64 {
        self.fps(macs) / self.power_w / 1000.0
    }
}

/// The common reference workload for Table IV: ViT-Tiny @ 96², RoI-masked
/// to the paper's ~67% pixel-skip operating point, plus the MGNet front end.
pub fn reference_workload_macs() -> u64 {
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    let kept = (cfg.num_patches() as f64 * 0.33).round() as usize;
    let backbone = Workload::vit(&cfg, kept, true);
    let mg = MgnetConfig::classification(96).as_vit();
    let mgw = Workload::vit(&mg, mg.num_patches(), true);
    backbone.total_macs() + mgw.total_macs()
}

/// The six competitors of Table IV.
///
/// Parameter provenance (each calibrated to its published efficiency at the
/// paper's consistent-area reconstruction; Table IV column in parentheses):
/// - **LightBulb** (57.75 KFPS/W): binarized photonic XNOR; huge raw rate but
///   64 bit-serial passes for 8-bit and ADC-heavy power.
/// - **HolyLight** (3.3): datacenter nanophotonic design; throughput-first,
///   power-hungry at edge scale.
/// - **HQNNA** (34.6): heterogeneous-quantization CNN accelerator.
/// - **ROBIN** (46.5): robust binary design, DAC/ADC-limited.
/// - **CrossLight** (10.78-52.59 best): cross-layer optimized, mid-range.
/// - **Lightator** (61.61-188.24 best): near-sensor compressive acquisition —
///   the one design whose best case exceeds Opto-ViT (Table IV shows -46.7%).
pub fn table_iv_competitors() -> Vec<SiphAccelerator> {
    let macs = reference_workload_macs();
    // Helper: derive power so the design lands at its published KFPS/W on
    // the common workload — the paper's own "reconstructed … ensured a
    // consistent area constraint" methodology (structure from publication,
    // efficiency anchored to Table IV).
    let anchored = |name,
                    node_nm,
                    area,
                    macs_per_cycle: f64,
                    clock: f64,
                    util: f64,
                    passes: f64,
                    published_kfpsw: f64| {
        let mut a = SiphAccelerator {
            name,
            node_nm,
            area_mm2: area,
            macs_per_cycle,
            clock_ghz: clock,
            vit_utilization: util,
            passes_for_8bit: passes,
            power_w: 1.0,
        };
        a.power_w = a.fps(macs) / (published_kfpsw * 1000.0);
        a
    };
    vec![
        anchored("LightBulb", Some(32), 30.0, 65536.0, 5.0, 0.55, 64.0, 57.75),
        anchored("HolyLight", Some(32), 60.0, 16384.0, 1.2, 0.45, 1.0, 3.3),
        anchored("HQNNA", Some(45), 40.0, 8192.0, 1.0, 0.50, 4.0, 34.6),
        anchored("ROBIN", Some(45), 25.0, 16384.0, 2.0, 0.50, 16.0, 46.5),
        anchored("CrossLight", None, 35.0, 8192.0, 1.0, 0.55, 2.0, 52.59),
        anchored("Lightator", Some(45), 22.0, 4096.0, 1.0, 0.70, 1.0, 188.24),
    ]
}

/// One Table-IV row (ours computed from the full model, theirs analytic).
#[derive(Debug, Clone)]
pub struct TableIvRow {
    pub name: String,
    pub node: String,
    pub kfps_per_watt: f64,
    /// Improvement of Opto-ViT over this design (the paper's `Improv.` row):
    /// `(ours - theirs) / theirs`, positive = we win.
    pub improvement_pct: f64,
}

/// Opto-ViT's own KFPS/W at the reference operating point, from the
/// architecture model.
pub fn optovit_kfps_per_watt() -> f64 {
    let m = AcceleratorModel::default();
    let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
    let mg = MgnetConfig::classification(96);
    let kept = (cfg.num_patches() as f64 * 0.33).round() as usize;
    1.0 / m.masked_energy(&cfg, &mg, kept).total_j() / 1000.0
}

/// Build the full Table IV.
pub fn table_iv() -> Vec<TableIvRow> {
    let macs = reference_workload_macs();
    let ours = optovit_kfps_per_watt();
    let mut rows: Vec<TableIvRow> = table_iv_competitors()
        .into_iter()
        .map(|a| {
            let theirs = a.kfps_per_watt(macs);
            TableIvRow {
                name: a.name.to_string(),
                node: a.node_nm.map(|n| n.to_string()).unwrap_or_else(|| "*".into()),
                kfps_per_watt: theirs,
                improvement_pct: (ours - theirs) / theirs * 100.0,
            }
        })
        .collect();
    rows.push(TableIvRow {
        name: "Opto-ViT".into(),
        node: "45".into(),
        kfps_per_watt: ours,
        improvement_pct: 0.0,
    });
    rows
}

/// Reference inference platforms (§IV, configurations of [54]): both run the
/// same INT8 ViT; numbers are the published measurements.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub kfps_per_watt: f64,
}

pub fn reference_platforms() -> Vec<Platform> {
    vec![
        Platform { name: "Xilinx VCK190 (INT8, EQ-ViT cfg)", kfps_per_watt: 1.42 },
        Platform { name: "NVIDIA A100 (INT8 TensorRT)", kfps_per_watt: 0.86 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn competitor_anchoring_reproduces_published_numbers() {
        let macs = reference_workload_macs();
        for a in table_iv_competitors() {
            let k = a.kfps_per_watt(macs);
            let expected = match a.name {
                "LightBulb" => 57.75,
                "HolyLight" => 3.3,
                "HQNNA" => 34.6,
                "ROBIN" => 46.5,
                "CrossLight" => 52.59,
                "Lightator" => 188.24,
                _ => unreachable!(),
            };
            assert!((k - expected).abs() / expected < 1e-9, "{}: {k} vs {expected}", a.name);
        }
    }

    #[test]
    fn optovit_outperforms_all_but_lightator_best() {
        let rows = table_iv();
        let ours = rows.last().unwrap().kfps_per_watt;
        for r in &rows[..rows.len() - 1] {
            if r.name == "Lightator" {
                assert!(r.kfps_per_watt > ours, "Lightator best case should exceed ours");
            } else {
                assert!(ours > r.kfps_per_watt, "{} {} !< ours {ours}", r.name, r.kfps_per_watt);
            }
        }
    }

    #[test]
    fn improvement_signs_match_table_iv() {
        for r in table_iv() {
            match r.name.as_str() {
                "Lightator" => assert!(r.improvement_pct < 0.0),
                "Opto-ViT" => assert_eq!(r.improvement_pct, 0.0),
                _ => assert!(r.improvement_pct > 0.0, "{}: {}", r.name, r.improvement_pct),
            }
        }
    }

    #[test]
    fn holylight_is_worst() {
        let macs = reference_workload_macs();
        let comps = table_iv_competitors();
        let holy = comps.iter().find(|a| a.name == "HolyLight").unwrap();
        for a in &comps {
            if a.name != "HolyLight" {
                assert!(a.kfps_per_watt(macs) > holy.kfps_per_watt(macs));
            }
        }
    }

    #[test]
    fn platforms_two_to_three_orders_below() {
        // §IV: Opto-ViT achieves two to three orders of magnitude greater
        // efficiency than VCK190/A100.
        let ours = optovit_kfps_per_watt();
        for p in reference_platforms() {
            let ratio = ours / p.kfps_per_watt;
            assert!((10.0..5000.0).contains(&ratio), "{}: ratio {ratio}", p.name);
        }
    }

    #[test]
    fn reference_workload_magnitude() {
        let m = reference_workload_macs();
        // Masked Tiny-96 + MGNet: order 100 MMACs.
        assert!((30_000_000..300_000_000).contains(&m), "macs {m}");
    }
}
