//! # Opto-ViT
//!
//! Full-stack reproduction of *"Opto-ViT: Architecting a Near-Sensor Region of
//! Interest-Aware Vision Transformer Accelerator with Silicon Photonics"*
//! (CS.AR 2025).
//!
//! The crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//!
//! - **L1** — Pallas kernels (`python/compile/kernels/`) emulating the
//!   photonic optical core (32-wavelength × 64-arm WDM matmul, 8-bit
//!   quantization, microring crosstalk), lowered at build time.
//! - **L2** — JAX ViT + MGNet models (`python/compile/model.py`), lowered once
//!   to HLO-text artifacts by `python/compile/aot.py`.
//! - **L3** — this crate: the near-sensor serving pipeline (sensor → MGNet →
//!   RoI mask → patch pruning → ViT backbone over a pluggable execution
//!   backend) plus the architecture simulator the paper's evaluation is
//!   built on — photonic device models, component energy/latency models,
//!   the five-core matrix-decompositional pipeline scheduler, and analytic
//!   models of competing SiPh accelerators.
//!
//! Execution is pluggable behind the [`runtime::Backend`] trait, mirroring
//! the paper's three evaluation substrates: `--backend pjrt` runs the
//! compiled HLO artifacts (Python never runs on the request path: after
//! `make artifacts` the rust binary is self-contained), `--backend host`
//! runs a pure-Rust quantized reference forward pass needing no artifacts
//! at all, and `--backend sim` keeps the host numerics while charging
//! modeled photonic-core latency from [`arch`]/[`energy`] — including,
//! when a [`cosim`] queueing plan is armed (`--cores`/`--arrival-fps`),
//! load-dependent waiting time from a discrete-event replay of the
//! scheduler under the actual arrival process.
//!
//! Execution is **batch-first**: [`runtime::Backend::execute_batch`] runs
//! one bucket artifact over N frames per call (all three backends
//! implement it natively), the coordinator accumulates routed frames in a
//! bucket-major [`coordinator::batcher::MicroBatcher`] behind a
//! `max_batch`/`max_wait` deadline policy, and serving **streams**:
//! [`coordinator::pipeline::serve`] returns a
//! [`coordinator::pipeline::FrameStream`] — an iterator of in-order
//! results with a bounded reassembly window — from which the terminal
//! `ServeReport` is derived.
//!
//! Serving is **session-oriented**: a long-lived
//! [`coordinator::server::Server`] owns the dispatcher → N workers →
//! reassembler machinery once (each worker constructing its own non-`Send`
//! backend via a [`runtime::BackendFactory`], optionally core-pinned), and
//! independent [`coordinator::server::Session`]s — one per camera/tenant —
//! submit frames under backpressure and drain per-session in-order
//! streams. Frames from all sessions share the workers' bucket-major
//! micro-batch lanes (cross-session amortization), admission is weighted
//! round-robin (a hot camera cannot starve the rest), and every session
//! gets its own `ServeReport` plus a server-wide aggregate. The batch-job
//! surfaces survive as documented wrappers: `optovit serve --workers N`
//! (`serve_sharded`) is the one-session case, `--cameras K` opens K
//! sessions over one server. The per-frame hot path is allocation-free in
//! steady state (see [`coordinator::pipeline::FrameScratch`]); `cargo
//! bench --bench serve_scaling` sweeps worker counts × batch sizes over
//! whichever backend is available and writes the machine-readable
//! `BENCH_serve.json` trajectory.
//!
//! Serving time is **deterministic by construction**: every deadline,
//! wait, and timestamp goes through the pluggable
//! [`coordinator::clock::Clock`] seam (system clock in production, a
//! step-controlled manual clock in tests — zero-cost for production
//! callers), which is what makes the per-session **QoS** layer provable:
//! latency SLOs with deadline-aware micro-batch flushes and per-session
//! `slo_miss`/p99 accounting, plus admission quotas (max in-flight +
//! token-bucket rate, rejected as the distinct `dropped_quota`). Knobs:
//! `optovit serve --cameras K --slo-ms F --quota N --rate F`; gate:
//! `cargo test --test qos` (sleep-free, exact expectations).
//!
//! The worker pool is **elastic**: with `--max-workers` above the
//! starting size the live server resizes without a restart —
//! [`coordinator::server::Server::scale_up`] spawns into the lowest
//! free slot (lowest free core under `--pin`),
//! [`coordinator::server::Server::scale_down`] drains and retires the
//! highest serving slot (its final stats row is retained so totals
//! stay monotone; a lone worker is never drained). `optovit serve
//! --autoscale` closes the loop with
//! [`coordinator::autoscale::AutoScaler`]: queue-depth / SLO-miss /
//! p99 signals walk a hysteresis ladder of scale-ups, lowest-weight
//! admission shedding at the cap (the distinct `dropped_shed`
//! counter), and cooled-down scale-downs, every decision logged as a
//! [`coordinator::autoscale::ScaleEvent`]. [`coordinator::loadgen`]
//! sweeps scripted arrival storms (step / burst / diurnal / Poisson)
//! through hundreds of sessions deterministically; gates: `cargo test
//! --test storm`, `cargo bench --bench serve_storm` →
//! `BENCH_storm.json`.
//!
//! Precision is a **per-tenant serving contract**: every session (and
//! `serve()` run) carries a [`quant::PrecisionPolicy`] — a fixed
//! [`quant::PrecisionTier`] (int4 / int8 / fp32) or `Auto`, which
//! resolves per frame from MGNet RoI density (dense scenes stay int8,
//! sparse ones drop to int4). Tiers never mix inside a micro-batch
//! (groups are bucket×tier-major), the energy model scales
//! converter-bound terms (DAC/ADC/VCSEL/MR weight programming) by tier
//! width, and `ServeReport` counts frames per tier plus an optional
//! fp32 agreement probe (`PipelineConfig::fp32_reference`) that never
//! pollutes latency or energy accounting. Knobs: `optovit serve --precision
//! auto|int4|int8|fp32`; gate: `cargo test --test precision`; bench:
//! `cargo bench --bench precision_sweep` → `BENCH_precision.json`.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`photonics`] | microring, crosstalk, FPV, VCSEL, BPD device models |
//! | [`energy`] | per-component energy/delay constants + accounting engine |
//! | [`arch`] | optical core cycle model, chunk mapping, 5-core scheduler, ViT workload inventory |
//! | [`cosim`] | discrete-event queueing co-sim of the mapped scheduler: per-core FIFO queues under the real arrival process, load-dependent modeled latency, operating-point sweeps |
//! | [`vit`] | ViT-T/S/B/L and MGNet configurations |
//! | [`quant`] | symmetric quantization + mixed-precision serving tiers (`PrecisionTier` int4/int8/fp32, per-tenant `PrecisionPolicy` incl. ROI-driven `Auto`) |
//! | [`roi`] | patch masks and skip-ratio accounting |
//! | [`sensor`] | synthetic CMOS sensor / video workload generator |
//! | [`runtime`] | pluggable batch-first execution backends behind the `Backend` trait (`execute_batch` = N frames/call, natively in all three): `pjrt` (compiled HLO), `host` (pure-Rust reference), `sim` (host numerics + batch-aware modeled photonic timing), plus per-worker `BackendFactory` construction |
//! | [`coordinator`] | the serving stack, generic over any backend: zero-allocation frame pipeline, bucket routing, deadline-aware bucket-major micro-batching (`MicroBatcher`), streaming `FrameStream` serve, the pluggable `Clock`/`Event` time seam, and the session-oriented `Server` (multi-tenant `Session`s over one dispatcher → N micro-batching, optionally core-pinned workers → per-session in-order reassembly, fair weighted admission, per-session QoS: latency SLOs + admission quotas, per-session + aggregate reports) — now elastic: `scale_up`/`scale_down`/`set_shed` on the live pool, the SLO-driven `autoscale::AutoScaler`, and the `loadgen` storm harness — with per-tenant mixed-precision: bucket×tier-major micro-batch groups, per-tier `tier_frames` accounting, and an optional fp32 agreement probe |
//! | [`baselines`] | Table-IV competitor accelerator models + platform refs |
//! | [`cli`] | dependency-free argument parsing |
//! | [`util`] | PRNG, stats, table formatting, property-test helpers |

pub mod arch;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod cosim;
pub mod energy;
pub mod photonics;
pub mod quant;
pub mod roi;
pub mod runtime;
pub mod sensor;
pub mod util;
pub mod vit;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
