//! Dependency-free command-line argument parsing (clap is unavailable in
//! the offline crate set).
//!
//! Grammar: `optovit <command> [--key value] [--key=value] [--flag]`.

use std::collections::BTreeMap;

/// Parsed arguments: a command plus key/value options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    return Err("bare '--' is not supported".into());
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    // Boolean flag.
                    out.opts.insert(rest.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Microsecond option as a `Duration`, e.g. `--batch-wait-us 500`.
    pub fn get_duration_us(
        &self,
        key: &str,
        default_us: u64,
    ) -> Result<std::time::Duration, String> {
        Ok(std::time::Duration::from_micros(self.get_u64(key, default_us)?))
    }

    /// Optional fractional-millisecond option as a `Duration`, e.g.
    /// `--slo-ms 2.5`. Absent → `Ok(None)`. Rejected: non-finite and
    /// non-positive values (a 0 ms SLO would mark every frame a miss;
    /// `inf` would panic `Duration::from_secs_f64`) and values over one
    /// hour (a deadline that far out would overflow nothing but means a
    /// typo, and `Instant + slo` arithmetic must stay safe).
    pub fn get_opt_duration_ms(
        &self,
        key: &str,
    ) -> Result<Option<std::time::Duration>, String> {
        const MAX_MS: f64 = 3_600_000.0; // one hour
        match self.get(key) {
            None => Ok(None),
            Some(v) => {
                let ms: f64 = v.parse().map_err(|e| format!("--{key}: {e}"))?;
                if !ms.is_finite() || ms <= 0.0 {
                    return Err(format!("--{key}: must be a positive number of milliseconds"));
                }
                if ms > MAX_MS {
                    return Err(format!("--{key}: {ms} ms is over the one-hour cap"));
                }
                Ok(Some(std::time::Duration::from_secs_f64(ms / 1000.0)))
            }
        }
    }

    /// Constrained string option: the value (or `default` when absent)
    /// must be one of `allowed`, e.g. `--backend pjrt|host|sim`.
    pub fn get_choice(
        &self,
        key: &str,
        allowed: &[&str],
        default: &str,
    ) -> Result<String, String> {
        let v = self.get(key).unwrap_or(default);
        if allowed.contains(&v) {
            Ok(v.to_string())
        } else {
            Err(format!("--{key}: unknown value '{v}' (choices: {})", allowed.join("|")))
        }
    }

    /// Reject unknown `--key` options: every parsed key must be in
    /// `known`. Commands with many knobs (`serve` grew `--cameras`,
    /// `--weights`, `--pin`, …) call this so a typo like `--camera 3`
    /// fails loudly instead of silently serving one camera.
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for key in self.opts.keys() {
            if !known.contains(&key.as_str()) {
                return Err(format!(
                    "unknown option --{key} (known: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }

    /// Comma-separated usize list, e.g. `--workers 1,2,4`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim())
                .filter(|s| !s.is_empty())
                .map(|s| s.parse::<usize>().map_err(|e| format!("--{key}: '{s}': {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn command_and_options() {
        let a = parse(&["serve", "--frames", "100", "--size=96", "--mask"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_u64("frames", 0).unwrap(), 100);
        assert_eq!(a.get_usize("size", 0).unwrap(), 96);
        assert!(a.get_bool("mask"));
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get_or("variant", "tiny"), "tiny");
        assert_eq!(a.get_f64("threshold", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn flag_before_next_flag_is_boolean() {
        let a = parse(&["run", "--fast", "--n", "3"]);
        assert!(a.get_bool("fast"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn positional_args_collected() {
        let a = parse(&["run", "x", "y"]);
        assert_eq!(a.positional(), &["x".to_string(), "y".to_string()]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["run", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn duration_us_parses_and_defaults() {
        let a = parse(&["serve", "--batch-wait-us", "250"]);
        assert_eq!(
            a.get_duration_us("batch-wait-us", 500).unwrap(),
            std::time::Duration::from_micros(250)
        );
        assert_eq!(
            a.get_duration_us("absent", 500).unwrap(),
            std::time::Duration::from_micros(500)
        );
        assert!(parse(&["serve", "--batch-wait-us", "x"]).get_duration_us("batch-wait-us", 0).is_err());
    }

    #[test]
    fn opt_duration_ms_parses_fractions_and_rejects_nonpositive() {
        let a = parse(&["serve", "--slo-ms", "2.5"]);
        assert_eq!(
            a.get_opt_duration_ms("slo-ms").unwrap(),
            Some(std::time::Duration::from_micros(2500))
        );
        assert_eq!(a.get_opt_duration_ms("absent").unwrap(), None);
        assert!(parse(&["serve", "--slo-ms", "0"]).get_opt_duration_ms("slo-ms").is_err());
        assert!(parse(&["serve", "--slo-ms", "-3"]).get_opt_duration_ms("slo-ms").is_err());
        assert!(parse(&["serve", "--slo-ms", "x"]).get_opt_duration_ms("slo-ms").is_err());
        // Non-finite and absurd values must fail validation, not panic
        // later in Duration/Instant arithmetic.
        assert!(parse(&["serve", "--slo-ms", "inf"]).get_opt_duration_ms("slo-ms").is_err());
        assert!(parse(&["serve", "--slo-ms", "NaN"]).get_opt_duration_ms("slo-ms").is_err());
        assert!(parse(&["serve", "--slo-ms", "1e30"]).get_opt_duration_ms("slo-ms").is_err());
    }

    #[test]
    fn choice_validates_against_allowed_set() {
        let a = parse(&["serve", "--backend", "host"]);
        assert_eq!(a.get_choice("backend", &["pjrt", "host", "sim"], "pjrt").unwrap(), "host");
        assert_eq!(a.get_choice("absent", &["x", "y"], "y").unwrap(), "y");
        let err = parse(&["serve", "--backend", "tpu"])
            .get_choice("backend", &["pjrt", "host", "sim"], "pjrt")
            .unwrap_err();
        assert!(err.contains("pjrt|host|sim"), "{err}");
    }

    #[test]
    fn check_known_flags_typos() {
        let a = parse(&["serve", "--cameras", "3", "--pin"]);
        assert!(a.check_known(&["cameras", "pin", "frames"]).is_ok());
        let err = a.check_known(&["camera", "frames"]).unwrap_err();
        assert!(err.contains("--cameras"), "{err}");
        assert!(err.contains("camera"), "{err}");
    }

    #[test]
    fn usize_list_parses_and_defaults() {
        let a = parse(&["bench", "--workers", "1,2,4"]);
        assert_eq!(a.get_usize_list("workers", &[8]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_usize_list("absent", &[1, 2]).unwrap(), vec![1, 2]);
        let spaced = parse(&["bench", "--workers", " 2, 3 "]);
        assert_eq!(spaced.get_usize_list("workers", &[]).unwrap(), vec![2, 3]);
        assert!(parse(&["bench", "--workers", "1,x"]).get_usize_list("workers", &[]).is_err());
    }
}
