//! Fixture: the session-server half with complete accounting and
//! justified atomics — zero findings expected.

use std::sync::atomic::{AtomicU64, Ordering};

use super::pipeline::ServeReport;

pub struct SessionCore {
    frames: AtomicU64,
    slo_miss: AtomicU64,
}

impl SessionCore {
    pub fn bump(&self) {
        // relaxed-ok: single-writer statistics counter; readers tolerate
        // a stale count.
        self.frames.fetch_add(1, Ordering::Relaxed);
    }

    pub fn miss(&self) {
        self.slo_miss.fetch_add(1, Ordering::Release);
    }

    pub fn lane(&self, lanes: &[u64], idx: usize) -> u64 {
        lanes[idx] // lint-allow(panic): idx is produced by enumerate() over this slice
    }

    /// Per-session accounting path: every `ServeReport` counter appears,
    /// the per-tier array included.
    fn to_report(&self) -> ServeReport {
        ServeReport {
            frames: self.frames.load(Ordering::Acquire),
            slo_miss: self.slo_miss.load(Ordering::Acquire),
            tier_frames: [0; 3],
            mean_batch: 0.0,
        }
    }
}

/// Aggregate accounting path: sums every counter, element-wise for the
/// per-tier array.
fn reassembler_loop(sessions: &[SessionCore]) -> ServeReport {
    let mut total = ServeReport::default();
    for s in sessions.iter() {
        total.frames += s.frames.load(Ordering::Acquire);
        total.slo_miss += s.slo_miss.load(Ordering::Acquire);
        let tiers = s.to_report().tier_frames;
        for (t, v) in total.tier_frames.iter_mut().zip(tiers) {
            *t += v;
        }
    }
    total
}
