//! Fixture: the same miniature pipeline with every violation repaired —
//! the linter must exit clean on this tree.

/// Terminal per-run report — the accounting-rule anchor; every counter
/// (scalar and the `[u64; 3]` per-tier array) appears in both accounting
/// paths in `server.rs`.
pub struct ServeReport {
    pub frames: u64,
    pub slo_miss: u64,
    pub tier_frames: [u64; 3],
    pub mean_batch: f64,
}

impl Default for ServeReport {
    fn default() -> Self {
        ServeReport { frames: 0, slo_miss: 0, tier_frames: [0; 3], mean_batch: 0.0 }
    }
}

/// No wall-clock read: the caller supplies the timestamp through the
/// clock seam.
pub fn first_frame(frames: &[u64]) -> Option<u64> {
    frames.first().copied()
}

/// No unwrap: defaults are explicit.
pub fn decode(v: Option<u64>) -> u64 {
    v.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_clock_and_panics_are_fine_in_tests() {
        let t = std::time::Instant::now();
        assert!(Some(t).map(|x| x.elapsed()).unwrap().as_secs() < 3600);
    }
}
