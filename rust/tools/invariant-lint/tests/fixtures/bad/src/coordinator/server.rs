//! Fixture: the session-server half — accounting paths plus atomics with
//! missing and malformed justifications.

use std::sync::atomic::{AtomicU64, Ordering};

use super::pipeline::ServeReport;

pub struct SessionCore {
    frames: AtomicU64,
    slo_miss: AtomicU64,
}

impl SessionCore {
    /// Untagged Relaxed: needs a `relaxed-ok` justification or an
    /// Acquire/Release upgrade.
    pub fn bump(&self) {
        self.frames.fetch_add(1, Ordering::Relaxed);
    }

    /// Malformed tag (reason too short) — the tag itself is a finding,
    /// and it grants nothing, so the Relaxed below stays flagged too.
    pub fn miss(&self) {
        // relaxed-ok: no
        self.slo_miss.fetch_add(1, Ordering::Relaxed);
    }

    /// Properly tagged panic site: not a finding.
    pub fn lane(&self, lanes: &[u64], idx: usize) -> u64 {
        lanes[idx] // lint-allow(panic): idx is produced by enumerate() over this slice
    }

    /// Per-session accounting path — `slo_miss` is missing (the seeded
    /// accounting violation). The per-tier counter array *is* populated
    /// here; its seeded violation is on the aggregate path below.
    fn to_report(&self) -> ServeReport {
        ServeReport {
            frames: self.frames.load(Ordering::Acquire),
            tier_frames: [0; 3],
            ..Default::default()
        }
    }
}

/// Aggregate accounting path: sums every scalar counter but drops the
/// `[u64; 3]` per-tier array (the seeded array-counter violation).
fn reassembler_loop(sessions: &[SessionCore]) -> ServeReport {
    let mut total = ServeReport::default();
    for s in sessions.iter() {
        total.frames += s.frames.load(Ordering::Acquire);
        total.slo_miss += s.slo_miss.load(Ordering::Acquire);
    }
    // Clock-seam escape: a raw sleep on the serving path.
    std::thread::sleep(std::time::Duration::from_millis(1));
    total
}
