//! Fixture: a miniature frame pipeline seeded with invariant violations
//! the linter must find (and test-only code it must ignore).

use std::time::Instant;

/// Terminal per-run report — the accounting-rule anchor. `slo_miss` is
/// deliberately dropped from the per-session path in `server.rs`, and
/// the `tier_frames` counter array from the aggregate path.
pub struct ServeReport {
    pub frames: u64,
    pub slo_miss: u64,
    pub tier_frames: [u64; 3],
    pub mean_batch: f64,
}

impl Default for ServeReport {
    fn default() -> Self {
        ServeReport { frames: 0, slo_miss: 0, tier_frames: [0; 3], mean_batch: 0.0 }
    }
}

/// Clock-seam escape: reads the wall clock outside `coordinator/clock.rs`.
pub fn stamp() -> Instant {
    Instant::now()
}

/// Untagged slice index on the serving path.
pub fn first_frame(frames: &[u64]) -> u64 {
    frames[0]
}

/// Untagged unwrap on the serving path.
pub fn decode(v: Option<u64>) -> u64 {
    v.unwrap()
}

/// "Instant::now() would be a violation here" — patterns inside string
/// literals and comments must not trigger (the lexer blanks them).
pub fn describe() -> &'static str {
    "call Instant::now() and .unwrap() at your peril"
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_clock_and_panics_are_fine_in_tests() {
        let t = std::time::Instant::now();
        assert!(Some(t).map(|x| x.elapsed()).unwrap().as_secs() < 3600);
    }
}
