//! End-to-end rule tests over the seeded fixture trees.
//!
//! `fixtures/bad` plants one of everything — a clock-seam escape, an
//! untagged unwrap + slice index, untagged and mis-tagged `Relaxed`
//! sites, a scalar `ServeReport` counter dropped from the per-session
//! accounting path, and a `[u64; 3]` per-tier counter array dropped
//! from the aggregate path — and this test pins the scanner to the **exact**
//! finding set (file, line, rule), so both false negatives (a seeded
//! violation slips through) and false positives (the count grows) fail.
//! `fixtures/clean` is the repaired twin and must scan to zero, the same
//! bar `cargo run -p invariant-lint` holds the real tree to in CI.

use std::path::{Path, PathBuf};

use invariant_lint::{scan_root, Rule};

fn fixture(tree: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(tree).join("src")
}

#[test]
fn bad_tree_yields_exactly_the_seeded_findings() {
    let report = scan_root(&fixture("bad")).expect("scan bad fixture");
    assert_eq!(report.files_scanned, 2);

    let got: Vec<(String, usize, Rule)> = report
        .violations
        .iter()
        .map(|v| (v.file.to_string_lossy().replace('\\', "/"), v.line, v.rule))
        .collect();
    let expected: Vec<(String, usize, Rule)> = [
        ("coordinator/pipeline.rs", 9, Rule::Accounting), // slo_miss off the per-session path
        ("coordinator/pipeline.rs", 9, Rule::Accounting), // tier_frames array off the aggregate path
        ("coordinator/pipeline.rs", 24, Rule::Clock),     // Instant::now()
        ("coordinator/pipeline.rs", 29, Rule::Panic),     // frames[0]
        ("coordinator/pipeline.rs", 34, Rule::Panic),     // v.unwrap()
        ("coordinator/server.rs", 17, Rule::Relaxed),     // untagged fetch_add
        ("coordinator/server.rs", 23, Rule::Accounting),  // reason-less relaxed-ok tag
        ("coordinator/server.rs", 24, Rule::Relaxed),     // the tag granted nothing
        ("coordinator/server.rs", 53, Rule::Clock),       // thread::sleep
    ]
    .into_iter()
    .map(|(f, l, r)| (f.to_string(), l, r))
    .collect();
    assert_eq!(got, expected, "finding set drifted from the seeded violations");

    // Per-rule totals, as a readable summary of the same pin.
    assert_eq!(report.count(Rule::Clock), 2);
    assert_eq!(report.count(Rule::Panic), 2);
    assert_eq!(report.count(Rule::Relaxed), 2);
    assert_eq!(report.count(Rule::Accounting), 3);
}

#[test]
fn bad_tree_messages_name_the_offense() {
    let report = scan_root(&fixture("bad")).expect("scan bad fixture");
    let messages: Vec<String> = report.violations.iter().map(|v| v.message.clone()).collect();
    assert!(messages.iter().any(|m| m.contains("Instant::now")), "{messages:?}");
    assert!(messages.iter().any(|m| m.contains("thread::sleep")), "{messages:?}");
    assert!(messages.iter().any(|m| m.contains("slo_miss")), "{messages:?}");
    // The `[u64; 3]` per-tier array is a counter too: dropping it from
    // the aggregate path must be named in a finding.
    assert!(
        messages.iter().any(|m| m.contains("tier_frames") && m.contains("reassembler_loop")),
        "{messages:?}"
    );
    assert!(messages.iter().any(|m| m.contains("Ordering::Relaxed")), "{messages:?}");
}

#[test]
fn clean_tree_scans_to_zero() {
    let report = scan_root(&fixture("clean")).expect("scan clean fixture");
    assert_eq!(report.files_scanned, 2);
    assert!(
        report.violations.is_empty(),
        "clean fixture must lint clean, got: {:#?}",
        report.violations
    );
}

/// The fixture trees exercise the tagged-and-ignored paths too: the
/// well-formed `lint-allow(panic)` on the slice index and the `relaxed-ok`
/// with a real reason appear in *both* trees and are never findings.
#[test]
fn well_formed_tags_suppress_in_both_trees() {
    for tree in ["bad", "clean"] {
        let report = scan_root(&fixture(tree)).expect("scan fixture");
        assert!(
            !report
                .violations
                .iter()
                .any(|v| v.rule == Rule::Panic && v.file.to_string_lossy().contains("server")),
            "{tree}: the tagged lane() slice index must not be a finding"
        );
    }
}
