//! CLI entry point: `cargo run -p invariant-lint [path-to-src]`.
//!
//! Scans `rust/src` (or the given root) with all four rules and exits
//! non-zero if any violation is found. Output is one `file:line: [rule]
//! message` per violation, sorted, so CI diffs are stable.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| {
        // tools/invariant-lint -> rust/src
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../src")
    });
    let report = match invariant_lint::scan_root(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("invariant-lint: failed to scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for v in &report.violations {
        println!("{v}");
    }
    let n = report.violations.len();
    if n == 0 {
        println!(
            "invariant-lint: {} files clean (clock-seam, no-panic, relaxed-audit, accounting)",
            report.files_scanned
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "invariant-lint: {n} violation(s) across {} files — see docs/coordinator \
             module map for the justification grammar",
            report.files_scanned
        );
        ExitCode::FAILURE
    }
}
