//! Source-level invariant linter for the Opto-ViT serving stack.
//!
//! The serving stack rests on invariants that `rustc` cannot see: all
//! timing flows through the pluggable `coordinator::clock::Clock`, the
//! serving hot path never panics, every `Ordering::Relaxed` atomic is a
//! deliberate decision, and every `ServeReport` counter composes from
//! per-session accumulators into the aggregate sum. This crate enforces
//! them as a CI step (`cargo run -p invariant-lint`) so they are
//! machine-checked on every PR instead of review-checked.
//!
//! # Rules
//!
//! 1. **clock-seam** (`clock`): no `Instant::now()`, `SystemTime::now()`,
//!    or `thread::sleep` anywhere in `rust/src` outside
//!    `coordinator/clock.rs` (the one place allowed to touch the wall
//!    clock) and `#[cfg(test)]` code. Violations either route through the
//!    owning `Clock` or carry a `lint-allow(clock)` justification.
//! 2. **no-panic** (`panic`): no `.unwrap()`, `.expect(`, `panic!`,
//!    `unreachable!`, `todo!`, `unimplemented!`, or slice-index
//!    expressions in the production code of the five serving hot-path
//!    modules (`coordinator/{server,pipeline,engine,batcher,autoscale}.rs`)
//!    unless tagged `lint-allow(panic)`. Plain `assert!` is deliberately
//!    not flagged: an assert is a declared invariant, not an accidental
//!    panic path.
//! 3. **relaxed-audit** (`relaxed`): every `Ordering::Relaxed` in
//!    production code needs a `relaxed-ok:` justification or an upgrade
//!    to `Acquire`/`Release`. The loom models in
//!    `rust/tests/loom_models.rs` verify the upgrades this audit forced
//!    (the `HealthSlot` publication pair and the clock `Event`
//!    generation counter) against real interleavings.
//! 4. **accounting** (`accounting`): every `u64` counter field of
//!    `ServeReport` — scalar `u64` and fixed-size `[u64; N]` counter
//!    arrays (the per-tier tallies) alike, plus the summed
//!    `modeled_queueing_s` — must appear in both the per-session
//!    accumulator path (`SessionAccum::to_report`) and the terminal
//!    aggregate path (`reassembler_loop`) in `coordinator/server.rs` —
//!    the "aggregate = exact per-session sum" convention every serving
//!    PR asserts.
//!
//! # Justification grammar
//!
//! A justification is a comment with a mandatory reason:
//!
//! ```text
//! // lint-allow(clock): <reason>        line/statement scope
//! // lint-allow(panic): <reason>
//! // lint-allow(panic, fn): <reason>    whole next fn item
//! // relaxed-ok: <reason>               shorthand for lint-allow(relaxed)
//! // relaxed-ok(fn): <reason>           fn-scoped shorthand
//! ```
//!
//! Scope: a tag on the same line as the finding covers that line. A tag
//! on a comment line of its own covers the statement that starts on the
//! next code line (tracked through multi-line calls by bracket depth; a
//! block opener `{` ends coverage at the header line so a tag can never
//! silently allow a whole block body).
//! The `fn` form, placed directly above a `fn` item (attributes in
//! between are fine), covers the whole function body — use it where one
//! reason genuinely applies to every site in the function, not to switch
//! a rule off wholesale. Reasons are mandatory; an empty reason is itself
//! a violation.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// The serving hot-path files the no-panic rule covers, matched as path
/// suffixes under the scanned root.
pub const PANIC_FREE_FILES: [&str; 5] = [
    "coordinator/server.rs",
    "coordinator/pipeline.rs",
    "coordinator/engine.rs",
    "coordinator/batcher.rs",
    "coordinator/autoscale.rs",
];

/// The one file allowed to read the wall clock.
pub const CLOCK_SEAM_FILE: &str = "coordinator/clock.rs";

/// Where `ServeReport` is defined (accounting rule anchor).
pub const REPORT_FILE: &str = "coordinator/pipeline.rs";

/// Where both accounting paths live (per-session + aggregate).
pub const ACCOUNTING_FILE: &str = "coordinator/server.rs";

/// Summed-`f64` fields held to the same per-session-sum convention as the
/// `u64` counters.
pub const SUMMED_F64_FIELDS: [&str; 1] = ["modeled_queueing_s"];

/// Which rule a violation belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    Clock,
    Panic,
    Relaxed,
    Accounting,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::Clock => "clock",
            Rule::Panic => "panic",
            Rule::Relaxed => "relaxed",
            Rule::Accounting => "accounting",
        }
    }

    fn from_tag(tag: &str) -> Option<Rule> {
        match tag {
            "clock" => Some(Rule::Clock),
            "panic" => Some(Rule::Panic),
            "relaxed" => Some(Rule::Relaxed),
            "accounting" => Some(Rule::Accounting),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the scanned root.
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.message)
    }
}

/// Outcome of a full scan.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
}

impl Report {
    pub fn count(&self, rule: Rule) -> usize {
        self.violations.iter().filter(|v| v.rule == rule).count()
    }
}

/// One source line split into its code and comment parts (string-literal
/// contents are blanked out of the code part, so patterns inside error
/// messages never trigger a rule).
#[derive(Debug, Default, Clone)]
struct LineView {
    code: String,
    comment: String,
}

/// Lexer state carried across lines.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Lex {
    Normal,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Split a file into per-line code/comment views. Handles line and
/// (nested) block comments, string/char literals, raw strings, and
/// lifetimes. This is a lexer, not a parser: it only needs to be exact
/// about *where code is*, not what it means.
fn split_lines(src: &str) -> Vec<LineView> {
    let mut out = Vec::new();
    let mut state = Lex::Normal;
    for raw in src.lines() {
        let mut view = LineView::default();
        let chars: Vec<char> = raw.chars().collect();
        let mut i = 0usize;
        // A line comment never carries over, but block comments and
        // (raw) strings do.
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match state {
                Lex::Normal => match c {
                    '/' if next == Some('/') => {
                        view.comment.push_str(&raw[byte_at(raw, i)..]);
                        i = chars.len();
                    }
                    '/' if next == Some('*') => {
                        state = Lex::BlockComment(1);
                        i += 2;
                    }
                    '"' => {
                        // Keep the delimiter so `""` stays visibly a
                        // string in the code view.
                        view.code.push('"');
                        state = Lex::Str;
                        i += 1;
                    }
                    'r' | 'b' if is_raw_string_start(&chars, i) => {
                        let (hashes, consumed) = raw_string_open(&chars, i);
                        view.code.push('"');
                        state = Lex::RawStr(hashes);
                        i += consumed;
                    }
                    '\'' => {
                        // Char literal vs lifetime: a literal closes with
                        // a quote within a few chars (escapes included);
                        // a lifetime never closes.
                        if let Some(len) = char_literal_len(&chars, i) {
                            view.code.push('\'');
                            view.code.push('\'');
                            i += len;
                        } else {
                            view.code.push('\'');
                            i += 1;
                        }
                    }
                    _ => {
                        view.code.push(c);
                        i += 1;
                    }
                },
                Lex::BlockComment(depth) => {
                    if c == '*' && next == Some('/') {
                        state = if depth == 1 {
                            Lex::Normal
                        } else {
                            Lex::BlockComment(depth - 1)
                        };
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        state = Lex::BlockComment(depth + 1);
                        i += 2;
                    } else {
                        view.comment.push(c);
                        i += 1;
                    }
                }
                Lex::Str => match c {
                    '\\' => i += 2,
                    '"' => {
                        view.code.push('"');
                        state = Lex::Normal;
                        i += 1;
                    }
                    _ => i += 1,
                },
                Lex::RawStr(hashes) => {
                    if c == '"' && closes_raw_string(&chars, i, hashes) {
                        view.code.push('"');
                        state = Lex::Normal;
                        i += 1 + hashes as usize;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        out.push(view);
    }
    out
}

/// Byte offset of the `i`-th char in `s` (lines are short; linear is fine).
fn byte_at(s: &str, i: usize) -> usize {
    s.char_indices().nth(i).map(|(b, _)| b).unwrap_or(s.len())
}

/// Is `chars[i]` the start of a raw (possibly byte) string: `r"`, `r#`,
/// `br"`, `br#` — and not just an identifier containing `r`?
fn is_raw_string_start(chars: &[char], i: usize) -> bool {
    if i > 0 {
        let p = chars[i - 1];
        if p.is_alphanumeric() || p == '_' {
            return false;
        }
    }
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// Number of `#`s and chars consumed by a raw-string opener at `i`.
fn raw_string_open(chars: &[char], i: usize) -> (u32, usize) {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    j += 1; // the `r`
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    j += 1; // the opening quote
    (hashes, j - i)
}

fn closes_raw_string(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// If `chars[i]` (a `'`) opens a char literal, its total length in chars;
/// `None` for a lifetime.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            // Escaped: find the closing quote within a small window
            // (`'\n'`, `'\x7f'`, `'\u{1F600}'`).
            for j in i + 3..(i + 12).min(chars.len()) {
                if chars[j] == '\'' {
                    return Some(j - i + 1);
                }
            }
            None
        }
        Some(_) if chars.get(i + 2) == Some(&'\'') => Some(3),
        _ => None,
    }
}

/// Lines covered by `#[cfg(test)]` items (the attribute, the item
/// header, and the item body through its closing brace).
fn test_regions(lines: &[LineView]) -> Vec<bool> {
    let mut test = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        if lines[i].code.contains("#[cfg(test)]") {
            let end = item_end(lines, i);
            for t in test.iter_mut().take(end + 1).skip(i) {
                *t = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    test
}

/// Last line of the item starting at (or just after) `start`: either a
/// braceless item ending in `;`, or the line closing the item's brace
/// block. Falls back to `start` at end of file.
fn item_end(lines: &[LineView], start: usize) -> usize {
    let mut depth = 0i64;
    let mut opened = false;
    for (j, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                ';' if !opened && depth == 0 => return j,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return j;
        }
    }
    lines.len() - 1
}

/// A parsed justification tag.
#[derive(Debug, Clone, Copy)]
struct Allow {
    rule: Rule,
    fn_scope: bool,
}

/// Parse every justification tag in a comment. Tags with a missing or
/// empty reason are returned as violations instead of allowances.
fn parse_allows(comment: &str) -> (Vec<Allow>, Vec<&'static str>) {
    let mut allows = Vec::new();
    let mut errors = Vec::new();
    for (needle, implied_rule) in
        [("lint-allow(", None), ("relaxed-ok", Some(Rule::Relaxed))]
    {
        let mut rest = comment;
        while let Some(pos) = rest.find(needle) {
            let after = &rest[pos + needle.len()..];
            let (rule, fn_scope, tail) = match implied_rule {
                // lint-allow(<rule>[, fn]): ...
                None => {
                    let Some(close) = after.find(')') else {
                        errors.push("malformed lint-allow tag: missing ')'");
                        break;
                    };
                    let inside = &after[..close];
                    let mut parts = inside.split(',').map(str::trim);
                    let rule_name = parts.next().unwrap_or("");
                    let fn_scope = parts.any(|p| p == "fn");
                    match Rule::from_tag(rule_name) {
                        Some(r) => (r, fn_scope, &after[close + 1..]),
                        None => {
                            errors.push("unknown rule in lint-allow tag");
                            rest = &after[close + 1..];
                            continue;
                        }
                    }
                }
                // relaxed-ok[(fn)]: ...
                Some(r) => {
                    let (fn_scope, tail) = if let Some(t) = after.strip_prefix("(fn)") {
                        (true, t)
                    } else {
                        (false, after)
                    };
                    // Without the colon this is a prose mention of the
                    // grammar, not a tag; it grants nothing, and any
                    // Relaxed it was meant to cover still gets flagged —
                    // self-correcting, so no error.
                    if !tail.starts_with(':') {
                        rest = tail;
                        continue;
                    }
                    (r, fn_scope, tail)
                }
            };
            let reason_ok = tail
                .strip_prefix(':')
                .map(|r| r.trim().len() >= 3)
                .unwrap_or(false);
            if reason_ok {
                allows.push(Allow { rule, fn_scope });
            } else {
                errors.push("justification tag without a reason (`: <why>` is mandatory)");
            }
            rest = tail;
        }
    }
    (allows, errors)
}

/// Per-line allowance map for each rule, built from the justification
/// comments. Malformed tags are reported as violations of the rule they
/// tried to allow (or `accounting` as a catch-all for unknown rules —
/// they still fail the build, which is the point).
fn allowance_map(
    lines: &[LineView],
    rel: &Path,
    violations: &mut Vec<Violation>,
) -> Vec<Vec<Rule>> {
    let mut allowed: Vec<Vec<Rule>> = vec![Vec::new(); lines.len()];
    for i in 0..lines.len() {
        if lines[i].comment.is_empty() {
            continue;
        }
        let (allows, errors) = parse_allows(&lines[i].comment);
        for e in errors {
            violations.push(Violation {
                file: rel.to_path_buf(),
                line: i + 1,
                rule: Rule::Accounting,
                message: e.to_string(),
            });
        }
        if allows.is_empty() {
            continue;
        }
        let own_code = !lines[i].code.trim().is_empty();
        for a in allows {
            if a.fn_scope {
                // Covers the next `fn` item (attributes in between are
                // fine) through its closing brace.
                let mut j = i;
                while j < lines.len() && !lines[j].code.contains("fn ") {
                    j += 1;
                }
                if j < lines.len() {
                    let end = item_end(lines, j);
                    for line_rules in allowed.iter_mut().take(end + 1).skip(i) {
                        line_rules.push(a.rule);
                    }
                }
            } else if own_code {
                allowed[i].push(a.rule);
            } else {
                // Comment-only line: cover the statement starting on the
                // next code line, tracked through multi-line calls by
                // bracket depth.
                let mut depth = 0i64;
                for j in i + 1..lines.len() {
                    allowed[j].push(a.rule);
                    let code = lines[j].code.trim();
                    if code.is_empty() {
                        continue;
                    }
                    for c in code.chars() {
                        match c {
                            '(' | '[' | '{' => depth += 1,
                            ')' | ']' | '}' => depth -= 1,
                            _ => {}
                        }
                    }
                    let last = code.chars().last().unwrap_or(' ');
                    // A block opener ends coverage at the header line —
                    // a tag must never silently allow a whole block body.
                    if last == '{' {
                        break;
                    }
                    if depth <= 0 && matches!(last, ';' | '}' | ',') {
                        break;
                    }
                }
            }
        }
    }
    allowed
}

fn is_allowed(allowed: &[Vec<Rule>], line: usize, rule: Rule) -> bool {
    allowed.get(line).map(|rs| rs.contains(&rule)).unwrap_or(false)
}

/// Slice-index positions in a code line: a `[` whose previous
/// non-whitespace char ends an indexable expression (identifier, `)`,
/// `]`, or `?`). Attribute (`#[`), macro (`vec![`), type (`: [u64; 4]`
/// and `&mut [bool]`), and slice-pattern (`&[..]`) brackets all have
/// other predecessors — a keyword directly before the `[` (`mut`, `dyn`,
/// `in`, …) means a type or literal position, not an index.
fn has_slice_index(code: &str) -> bool {
    let chars: Vec<char> = code.chars().collect();
    for (i, &c) in chars.iter().enumerate() {
        if c != '[' {
            continue;
        }
        let Some(mut j) = chars[..i].iter().rposition(|c| !c.is_whitespace()) else {
            continue;
        };
        let p = chars[j];
        if !(p.is_alphanumeric() || p == '_' || p == ')' || p == ']' || p == '?') {
            continue;
        }
        if p.is_alphanumeric() || p == '_' {
            let end = j + 1;
            while j > 0 && (chars[j - 1].is_alphanumeric() || chars[j - 1] == '_') {
                j -= 1;
            }
            let word: String = chars[j..end].iter().collect();
            if matches!(
                word.as_str(),
                "mut" | "dyn" | "in" | "return" | "else" | "box" | "const" | "as"
            ) {
                continue;
            }
        }
        return true;
    }
    false
}

fn path_matches(rel: &Path, suffix: &str) -> bool {
    let rel = rel.to_string_lossy().replace('\\', "/");
    rel == suffix || rel.ends_with(&format!("/{suffix}"))
}

/// Whether `ident` occurs with identifier boundaries in `haystack`.
fn contains_word(haystack: &str, ident: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = haystack[start..].find(ident) {
        let abs = start + pos;
        let before = haystack[..abs].chars().next_back();
        let after = haystack[abs + ident.len()..].chars().next();
        let boundary = |c: Option<char>| {
            c.map(|c| !(c.is_alphanumeric() || c == '_')).unwrap_or(true)
        };
        if boundary(before) && boundary(after) {
            return true;
        }
        start = abs + ident.len();
    }
    false
}

/// Scan one already-lexed file with the line-local rules (1–3).
fn scan_file(rel: &Path, lines: &[LineView], violations: &mut Vec<Violation>) {
    let test = test_regions(lines);
    let allowed = allowance_map(lines, rel, violations);
    let clock_exempt = path_matches(rel, CLOCK_SEAM_FILE);
    let panic_free = PANIC_FREE_FILES.iter().any(|f| path_matches(rel, f));

    for (i, line) in lines.iter().enumerate() {
        if test[i] || line.code.trim().is_empty() {
            continue;
        }
        let code = &line.code;

        // Rule 1: clock-seam.
        if !clock_exempt && !is_allowed(&allowed, i, Rule::Clock) {
            for pat in ["Instant::now", "SystemTime::now", "thread::sleep"] {
                if code.contains(pat) {
                    violations.push(Violation {
                        file: rel.to_path_buf(),
                        line: i + 1,
                        rule: Rule::Clock,
                        message: format!(
                            "`{pat}` outside coordinator/clock.rs — route through the \
                             owning `Clock` (or tag `lint-allow(clock): <reason>`)"
                        ),
                    });
                }
            }
        }

        // Rule 2: no-panic serving path.
        if panic_free && !is_allowed(&allowed, i, Rule::Panic) {
            let panics = [
                (".unwrap()", "unwrap"),
                (".expect(", "expect"),
                ("panic!", "panic!"),
                ("unreachable!", "unreachable!"),
                ("todo!", "todo!"),
                ("unimplemented!", "unimplemented!"),
            ];
            for (pat, what) in panics {
                if code.contains(pat) {
                    violations.push(Violation {
                        file: rel.to_path_buf(),
                        line: i + 1,
                        rule: Rule::Panic,
                        message: format!(
                            "`{what}` on the serving path — convert to `ServeError` via \
                             `guard`/`recover` (or tag `lint-allow(panic): <reason>`)"
                        ),
                    });
                }
            }
            if has_slice_index(code) {
                violations.push(Violation {
                    file: rel.to_path_buf(),
                    line: i + 1,
                    rule: Rule::Panic,
                    message: "slice-index on the serving path — use `.get()` or tag \
                              `lint-allow(panic): <reason>` stating the bounds invariant"
                        .to_string(),
                });
            }
        }

        // Rule 3: relaxed-ordering audit.
        if code.contains("Ordering::Relaxed") && !is_allowed(&allowed, i, Rule::Relaxed) {
            violations.push(Violation {
                file: rel.to_path_buf(),
                line: i + 1,
                rule: Rule::Relaxed,
                message: "`Ordering::Relaxed` without a `relaxed-ok: <reason>` \
                          justification — upgrade to Acquire/Release on publish sites \
                          (see tests/loom_models.rs) or justify"
                    .to_string(),
            });
        }
    }
}

/// Is `ty` a fixed-size `[u64; N]` counter array? Per-tier tallies are
/// held to the same convention as scalar counters: the aggregate is the
/// element-wise per-session sum.
fn is_u64_array(ty: &str) -> bool {
    ty.strip_prefix('[')
        .and_then(|t| t.strip_suffix(']'))
        .and_then(|t| t.split_once(';'))
        .map(|(elem, len)| elem.trim() == "u64" && len.trim().parse::<usize>().is_ok())
        .unwrap_or(false)
}

/// `u64` (scalar or `[u64; N]` array) fields of `pub struct ServeReport`
/// in the lexed report file.
fn serve_report_counters(lines: &[LineView]) -> Option<(usize, Vec<String>)> {
    let start = lines
        .iter()
        .position(|l| l.code.contains("pub struct ServeReport"))?;
    let end = item_end(lines, start);
    let mut fields = Vec::new();
    for line in lines.iter().take(end + 1).skip(start + 1) {
        let code = line.code.trim();
        if let Some(rest) = code.strip_prefix("pub ") {
            if let Some((name, ty)) = rest.split_once(':') {
                let ty = ty.trim().trim_end_matches(',');
                let name = name.trim();
                if ty == "u64" || is_u64_array(ty) || SUMMED_F64_FIELDS.contains(&name) {
                    fields.push(name.to_string());
                }
            }
        }
    }
    Some((start, fields))
}

/// Body line range of the first `fn <name>` in the lexed file.
fn fn_body(lines: &[LineView], name: &str) -> Option<(usize, usize)> {
    let pat = format!("fn {name}");
    let start = lines.iter().position(|l| {
        l.code.contains(&pat)
            && l.code[l.code.find(&pat).unwrap() + pat.len()..]
                .chars()
                .next()
                .map(|c| c == '(' || c == '<' || c.is_whitespace())
                .unwrap_or(true)
    })?;
    Some((start, item_end(lines, start)))
}

/// Rule 4: accounting convention over the whole tree.
fn scan_accounting(
    files: &BTreeMap<PathBuf, Vec<LineView>>,
    violations: &mut Vec<Violation>,
) {
    let report = files.iter().find(|(p, _)| path_matches(p, REPORT_FILE));
    let server = files.iter().find(|(p, _)| path_matches(p, ACCOUNTING_FILE));
    let (Some((report_path, report_lines)), Some((server_path, server_lines))) =
        (report, server)
    else {
        // A partial tree (fixtures) without both anchors has nothing to
        // check — rule 4 only fires on trees that define ServeReport.
        return;
    };
    let Some((struct_line, counters)) = serve_report_counters(report_lines) else {
        violations.push(Violation {
            file: report_path.clone(),
            line: 1,
            rule: Rule::Accounting,
            message: "`pub struct ServeReport` not found — the accounting rule lost its \
                      anchor; update invariant-lint if the struct moved"
                .to_string(),
        });
        return;
    };
    let anchors = [
        ("to_report", "per-session accumulator path (SessionAccum::to_report)"),
        ("reassembler_loop", "terminal aggregate path (reassembler_loop)"),
    ];
    for (fn_name, describe) in anchors {
        let Some((body_start, body_end)) = fn_body(server_lines, fn_name) else {
            violations.push(Violation {
                file: server_path.clone(),
                line: 1,
                rule: Rule::Accounting,
                message: format!(
                    "`fn {fn_name}` not found — the accounting rule lost its anchor; \
                     update invariant-lint if the function was renamed"
                ),
            });
            continue;
        };
        for counter in &counters {
            let present = server_lines[body_start..=body_end]
                .iter()
                .any(|l| contains_word(&l.code, counter));
            if !present {
                violations.push(Violation {
                    file: report_path.clone(),
                    line: struct_line + 1,
                    rule: Rule::Accounting,
                    message: format!(
                        "ServeReport counter `{counter}` missing from the {describe} — \
                         every counter must flow through both the per-session and \
                         aggregate-sum paths"
                    ),
                });
            }
        }
    }
}

fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(root)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root` with all four rules.
pub fn scan_root(root: &Path) -> std::io::Result<Report> {
    let mut paths = Vec::new();
    collect_rs_files(root, &mut paths)?;
    let mut violations = Vec::new();
    let mut lexed: BTreeMap<PathBuf, Vec<LineView>> = BTreeMap::new();
    for path in &paths {
        let src = fs::read_to_string(path)?;
        let rel = path.strip_prefix(root).unwrap_or(path).to_path_buf();
        lexed.insert(rel, split_lines(&src));
    }
    for (rel, lines) in &lexed {
        scan_file(rel, lines, &mut violations);
    }
    scan_accounting(&lexed, &mut violations);
    violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(Report { violations, files_scanned: paths.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lex(src: &str) -> Vec<LineView> {
        split_lines(src)
    }

    #[test]
    fn lexer_strips_strings_and_comments() {
        let lines = lex("let x = \"Instant::now()\"; // Instant::now()\n");
        assert!(!lines[0].code.contains("Instant::now"));
        assert!(lines[0].comment.contains("Instant::now"));
    }

    #[test]
    fn lexer_handles_raw_strings_and_chars() {
        let lines = lex("let s = r#\"a \"quoted\" panic!()\"#; let c = '\"'; s.len()[0];");
        assert!(!lines[0].code.contains("panic!"));
        assert!(lines[0].code.contains("len()[0]"));
    }

    #[test]
    fn lexer_tracks_block_comments_across_lines() {
        let lines = lex("/* start\n Instant::now()\n */ let x = 1;");
        assert!(lines[1].code.is_empty());
        assert!(lines[1].comment.contains("Instant::now"));
        assert!(lines[2].code.contains("let x = 1;"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lines = lex("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(lines[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn slice_index_detection() {
        assert!(has_slice_index("let x = v[i];"));
        assert!(has_slice_index("foo()[0]"));
        assert!(has_slice_index("&self.buf[..n]"));
        assert!(!has_slice_index("#[derive(Debug)]"));
        assert!(!has_slice_index("let v = vec![1, 2];"));
        assert!(!has_slice_index("counts: [u64; 4],"));
        assert!(!has_slice_index("fn f(x: &[u32]) {}"));
        assert!(!has_slice_index("alive: &mut [bool],"));
        assert!(!has_slice_index("for x in [1, 2] {}"));
    }

    #[test]
    fn test_region_tracking_covers_mod_tests() {
        let src = "fn a() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn b() { y.unwrap(); }\n}\n";
        let lines = lex(src);
        let t = test_regions(&lines);
        assert_eq!(t, vec![false, true, true, true, true]);
    }

    #[test]
    fn allow_tags_require_reasons() {
        let (allows, errors) = parse_allows(" relaxed-ok: single-writer counter");
        assert_eq!(allows.len(), 1);
        assert!(errors.is_empty());
        let (allows, errors) = parse_allows(" relaxed-ok:");
        assert!(allows.is_empty());
        assert_eq!(errors.len(), 1);
        // A colon-less mention is prose, not a tag: no allow, no error.
        let (allows, errors) = parse_allows(" each carries a relaxed-ok justification");
        assert!(allows.is_empty() && errors.is_empty());
        let (allows, _) = parse_allows(" lint-allow(panic, fn): slot ids pool-validated");
        assert!(allows[0].fn_scope);
        assert_eq!(allows[0].rule, Rule::Panic);
    }

    #[test]
    fn u64_array_counter_types() {
        assert!(is_u64_array("[u64; 3]"));
        assert!(is_u64_array("[ u64 ; 16 ]"));
        assert!(!is_u64_array("u64"));
        assert!(!is_u64_array("[f64; 3]"));
        assert!(!is_u64_array("[u64]"));
        assert!(!is_u64_array("Vec<u64>"));
        assert!(!is_u64_array("[u64; N]"));
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("dropped += s.rejected;", "dropped"));
        assert!(!contains_word("dropped_quota += 1;", "dropped"));
    }
}
