//! The batch-first execution gate: `Backend::execute_batch` over B frames
//! must be **bitwise-identical** to B sequential `execute` calls for every
//! bucket in the serving ladder, the bucket-major pipeline batch path must
//! match the per-frame fast path, and the streaming `serve` surface must
//! emit in order under a batching policy. Everything here runs on the
//! artifact-free host/sim backends, so CI gates the batched path with no
//! Python and no compiled HLO (an explicit step in `ci.yml`).

use std::time::Duration;

use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::pipeline::{serve, Pipeline, PipelineConfig, ServeOptions};
use optovit::runtime::{Backend, HostBackend, HostConfig, SimBackend, TensorRef};
use optovit::sensor::VideoSource;
use optovit::util::rng::Rng;

/// One encoder block keeps debug-mode forwards cheap while exercising the
/// full dataflow (embed → masked attention → FFN → head).
fn host_cfg() -> HostConfig {
    HostConfig { depth_limit: Some(1), ..HostConfig::default() }
}

const PATCH_DIM: usize = 16 * 16 * 3;

/// Deterministic pseudo-random backbone inputs for a bucket: patches,
/// ascending in-grid positions, and a validity prefix.
fn bucket_inputs(bucket: usize, valid_slots: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut patches = vec![0.0f32; bucket * PATCH_DIM];
    rng.fill_uniform_f32(&mut patches, 0.0, 1.0);
    let pos: Vec<f32> = (0..bucket).map(|i| i as f32).collect();
    let valid: Vec<f32> = (0..bucket).map(|i| if i < valid_slots { 1.0 } else { 0.0 }).collect();
    (patches, pos, valid)
}

/// The ISSUE acceptance gate: for every bucket in the tiny-96 ladder,
/// `execute_batch` over B frames equals B sequential `execute` calls
/// bitwise — and the MGNet artifact batches identically too.
#[test]
fn host_execute_batch_bitwise_equals_sequential_across_the_ladder() {
    const B: usize = 3;
    let ladder = PipelineConfig::tiny_96().buckets;
    let mut backend = HostBackend::new(host_cfg());
    for &bucket in &ladder {
        let artifact = PipelineConfig::tiny_96().backbone_artifact(bucket);
        let frames: Vec<_> = (0..B)
            .map(|i| bucket_inputs(bucket, bucket - i.min(bucket - 1), 1000 + i as u64))
            .collect();
        let bdims = [bucket as i64, PATCH_DIM as i64];
        let vdims = [bucket as i64];
        let holders: Vec<[TensorRef<'_>; 3]> = frames
            .iter()
            .map(|(p, pos, valid)| {
                [
                    TensorRef::new(p, &bdims),
                    TensorRef::new(pos, &vdims),
                    TensorRef::new(valid, &vdims),
                ]
            })
            .collect();
        let batch: Vec<&[TensorRef<'_>]> = holders.iter().map(|h| &h[..]).collect();
        let batched = backend.execute_batch(&artifact, &batch).expect("batched execute");
        assert_eq!(batched.len(), B);
        for (i, inputs) in holders.iter().enumerate() {
            let sequential = backend.execute(&artifact, inputs).expect("sequential execute");
            assert_eq!(
                batched[i], sequential,
                "bucket {bucket}, frame {i}: batched logits diverged from sequential"
            );
        }
    }
    // MGNet batches identically as well (full grid, one input).
    let mut rng = Rng::new(7);
    let mut xa = vec![0.0f32; 36 * PATCH_DIM];
    let mut xb = vec![0.0f32; 36 * PATCH_DIM];
    rng.fill_uniform_f32(&mut xa, 0.0, 1.0);
    rng.fill_uniform_f32(&mut xb, 0.0, 1.0);
    let dims = [36i64, PATCH_DIM as i64];
    let fa = [TensorRef::new(&xa, &dims)];
    let fb = [TensorRef::new(&xb, &dims)];
    let batch: Vec<&[TensorRef<'_>]> = vec![&fa, &fb];
    let batched = backend.execute_batch("mgnet_96", &batch).expect("mgnet batch");
    assert_eq!(batched[0], backend.execute("mgnet_96", &fa).expect("mgnet a"));
    assert_eq!(batched[1], backend.execute("mgnet_96", &fb).expect("mgnet b"));
}

/// The sim backend shares the host numerics on the batched entry and its
/// batch-aware latency model charges followers strictly less.
#[test]
fn sim_batches_host_numerics_with_amortized_latency() {
    let mut sim = SimBackend::new(host_cfg());
    let mut host = HostBackend::new(host_cfg());
    let (patches, pos, valid) = bucket_inputs(9, 5, 99);
    let bdims = [9i64, PATCH_DIM as i64];
    let vdims = [9i64];
    let frame = [
        TensorRef::new(&patches, &bdims),
        TensorRef::new(&pos, &vdims),
        TensorRef::new(&valid, &vdims),
    ];
    let batch: Vec<&[TensorRef<'_>]> = vec![&frame, &frame];
    let artifact = PipelineConfig::tiny_96().backbone_artifact(9);
    let batched_sim = sim.execute_batch(&artifact, &batch).expect("sim batch");
    let host_out = host.execute(&artifact, &frame).expect("host");
    assert_eq!(batched_sim[0], host_out, "sim batched numerics must be host numerics");
    assert_eq!(batched_sim[1], host_out);
    // Loading captured the configs, so the latency model is live: batch
    // followers amortize the backbone weight-programming share (the MGNet
    // stage runs per frame, so it stays constant).
    sim.load("mgnet_96").expect("load mgnet");
    let first = sim.modeled_stages_s(5, true, true).expect("first-in-batch stages");
    let follow = sim.modeled_stages_s(5, true, false).expect("follower stages");
    assert_eq!(follow.mgnet_s, first.mgnet_s);
    assert!(follow.backbone_s < first.backbone_s);
    assert!(follow.total_s() > 0.0);
}

/// Streaming serve under a batching policy: in-order emission, report
/// derived from the drained stream, and batch sizes recorded.
#[test]
fn streaming_serve_batches_and_stays_in_order() {
    let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), HostBackend::new(host_cfg()))
        .expect("pipeline");
    let opts = ServeOptions {
        sensor_seed: 3,
        batch: BatchPolicy::batched(3, Duration::from_millis(2)),
        window: 6,
        ..ServeOptions::frames(9)
    };
    let stream = serve(&mut p, &opts).expect("stream");
    let mut indices = Vec::new();
    let mut results = Vec::new();
    for r in stream {
        let r = r.expect("streamed frame");
        indices.push(r.frame_index);
        results.push(r);
    }
    assert_eq!(results.len(), 9, "the stream must deliver every requested frame");
    for w in indices.windows(2) {
        assert!(w[0] < w[1], "stream emitted out of order: {indices:?}");
    }
    assert!(p.metrics.mean_batch() >= 1.0);
    assert_eq!(p.metrics.frames(), 9);
}

/// `process_batch` (bucket-major grouping) equals the per-frame fast path
/// frame by frame, and a follower in a same-bucket group models less
/// energy — the dispatch-amortization the batch API exists for.
#[test]
fn pipeline_batch_path_matches_fast_path() {
    let mut src = VideoSource::new(96, 2, 17);
    let frames: Vec<_> = (0..4).map(|_| src.next_frame()).collect();
    let mut batch_p =
        Pipeline::with_backend(PipelineConfig::tiny_96(), HostBackend::new(host_cfg()))
            .expect("batch pipeline");
    let mut frame_p =
        Pipeline::with_backend(PipelineConfig::tiny_96(), HostBackend::new(host_cfg()))
            .expect("frame pipeline");
    let batched = batch_p.process_batch(&frames).expect("process_batch");
    let mut any_follower = false;
    let mut seen_buckets = std::collections::BTreeSet::new();
    for (frame, r) in frames.iter().zip(&batched) {
        let direct = frame_p.process_frame(frame).expect("process_frame");
        assert_eq!(r.logits, direct.logits, "batched numerics must match the fast path");
        assert_eq!(r.bucket, direct.bucket);
        assert_eq!(r.mask, direct.mask);
        if seen_buckets.insert(r.bucket) {
            // First frame of its bucket group: pays the full modeled
            // energy, exactly like the per-frame fast path.
            assert_eq!(
                r.modeled_energy_j, direct.modeled_energy_j,
                "a group's first frame pays the full modeled energy"
            );
        } else {
            // Follower: same frame, same kept count — strictly cheaper
            // than the fast path charged it.
            any_follower = true;
            assert!(
                r.modeled_energy_j < direct.modeled_energy_j,
                "follower must amortize energy ({} !< {})",
                r.modeled_energy_j,
                direct.modeled_energy_j
            );
        }
    }
    // With 4 frames over a 4-bucket ladder a shared bucket is likely but
    // not guaranteed; exercise the guaranteed case explicitly.
    if !any_follower {
        let rf_a = batch_p.route_frame(&frames[0]).expect("route");
        let rf_b = batch_p.route_frame(&frames[0]).expect("route");
        let rs = batch_p.complete_batch(vec![rf_a, rf_b]).expect("complete");
        assert!(rs[1].modeled_energy_j < rs[0].modeled_energy_j);
    }
}
