//! Engine-level tests over mock workers (no PJRT needed): in-order
//! reassembly under uneven worker latency, merged metrics accounting,
//! routing stability under sharding, and failure paths that must fail the
//! run instead of hanging the dispatcher.
//!
//! Wall-clock audit (the qos/clock PR): the sleeps in `MockWorker` are
//! workload *shaping* (uneven latency, a stalled first frame), never
//! synchronization — every assertion below is completion-based (exact
//! frame counts, strict ordering, run-terminates bounds), so no test
//! outcome depends on how long a sleep actually took. Timing-*semantics*
//! tests (deadline flushes, SLO misses, quotas) live in
//! `rust/tests/qos.rs` on a manual clock instead.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::{run, EngineConfig, FrameWorker};
use optovit::coordinator::pipeline::FrameResult;
use optovit::coordinator::{BucketRouter, StageMetrics};
use optovit::sensor::Frame;

const PATCH_PX: usize = 16;

#[derive(Clone, Copy)]
enum Behavior {
    /// Sleep `(frame.index % 3) * base` — uneven, index-dependent latency.
    Uneven(Duration),
    /// Panic on any frame with index >= n.
    PanicAt(u64),
    /// Return an error on any frame with index >= n.
    ErrAt(u64),
    /// Stall only on frame index 0 — lets every other worker race ahead,
    /// flooding the reassembler with out-of-order results.
    StallFirst(Duration),
}

/// Deterministic stand-in for a `Pipeline`: routes via the real
/// `BucketRouter` from the ground-truth mask, so results depend only on
/// the frame — never on which worker processed it.
struct MockWorker {
    router: BucketRouter,
    metrics: StageMetrics,
    behavior: Behavior,
}

impl MockWorker {
    fn new(behavior: Behavior) -> Self {
        MockWorker { router: BucketRouter::even(36, 4), metrics: StageMetrics::new(), behavior }
    }
}

impl FrameWorker for MockWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        match self.behavior {
            Behavior::Uneven(base) => std::thread::sleep(base * (frame.index % 3) as u32),
            Behavior::PanicAt(n) if frame.index >= n => panic!("mock worker panic"),
            Behavior::ErrAt(n) if frame.index >= n => bail!("mock worker error"),
            Behavior::StallFirst(d) if frame.index == 0 => std::thread::sleep(d),
            _ => {}
        }
        let mask = frame.gt_mask(PATCH_PX);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        self.metrics.record_stage("total", 1e-4);
        self.metrics.record_frame(1e-5, kept);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: 1e-4,
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: optovit::quant::PrecisionTier::Int8,
            fp32_agreement: None,
        })
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }
}

fn test_cfg(workers: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new(workers, PATCH_PX, 96);
    cfg.warmup_timeout_s = 10.0;
    cfg.stall_timeout_s = 5.0;
    cfg
}

#[test]
fn in_order_reassembly_under_uneven_latency() {
    let cfg = test_cfg(3);
    let mut seen = Vec::new();
    let (report, _merged) = run(
        |_w| Ok(MockWorker::new(Behavior::Uneven(Duration::from_millis(2)))),
        &cfg,
        60,
        |r| seen.push(r.frame_index),
    )
    .expect("sharded run");
    assert_eq!(report.frames, 60);
    assert_eq!(report.workers, 3);
    assert_eq!(seen.len(), 60);
    for w in seen.windows(2) {
        assert!(w[0] < w[1], "results out of order: {seen:?}");
    }
}

#[test]
fn merged_metrics_equal_sum_of_workers() {
    let cfg = test_cfg(4);
    let (report, merged) = run(
        |_w| Ok(MockWorker::new(Behavior::Uneven(Duration::from_millis(1)))),
        &cfg,
        80,
        |_r| {},
    )
    .expect("sharded run");
    assert_eq!(report.frames, 80);
    assert_eq!(report.backend, "custom", "mock workers carry the default backend name");
    assert_eq!(report.per_worker.len(), 4);
    // Every processed frame is accounted to exactly one worker, and the
    // merged metrics carry the union of all per-worker samples.
    let sum: u64 = report.per_worker.iter().map(|w| w.frames).sum();
    assert_eq!(sum, 80);
    assert_eq!(merged.frames(), 80);
    let rows = merged.stage_rows();
    let total = rows.iter().find(|r| r.0 == "total").expect("total stage recorded");
    assert_eq!(total.3, 80);
    assert!((merged.mean_energy_j() - 1e-5).abs() < 1e-12);
    assert!((report.mean_latency_s - 1e-4).abs() < 1e-12);
    for w in &report.per_worker {
        assert!(w.utilization >= 0.0 && w.utilization <= 1.0);
    }
}

#[test]
fn routing_unchanged_under_sharding() {
    // Same sensor seed → frame index i has identical content in both runs,
    // so every frame served by both must route to the same bucket.
    let mut single: BTreeMap<u64, usize> = BTreeMap::new();
    let (r1, _) = run(
        |_w| Ok(MockWorker::new(Behavior::Uneven(Duration::ZERO))),
        &test_cfg(1),
        50,
        |r| {
            single.insert(r.frame_index, r.bucket);
        },
    )
    .expect("1-worker run");
    let mut sharded: BTreeMap<u64, usize> = BTreeMap::new();
    let (r4, _) = run(
        |_w| Ok(MockWorker::new(Behavior::Uneven(Duration::ZERO))),
        &test_cfg(4),
        50,
        |r| {
            sharded.insert(r.frame_index, r.bucket);
        },
    )
    .expect("4-worker run");
    assert_eq!(r1.frames, 50);
    assert_eq!(r4.frames, 50);
    let mut common = 0usize;
    for (idx, bucket) in &single {
        if let Some(b) = sharded.get(idx) {
            assert_eq!(b, bucket, "bucket differs for frame {idx} under sharding");
            common += 1;
        }
    }
    assert!(common > 0, "runs served disjoint frame sets — cannot compare routing");
}

#[test]
fn worker_micro_batching_preserves_order_and_counts() {
    // Workers collect up to 4 frames per process_batch call (the default
    // FrameWorker::process_batch loops process, so results are unchanged);
    // reassembly must still be complete and strictly in order.
    let mut cfg = test_cfg(2);
    cfg.batch = BatchPolicy::batched(4, Duration::from_millis(2));
    let mut seen = Vec::new();
    let (report, merged) = run(
        |_w| Ok(MockWorker::new(Behavior::Uneven(Duration::ZERO))),
        &cfg,
        40,
        |r| seen.push(r.frame_index),
    )
    .expect("batched sharded run");
    assert_eq!(report.frames, 40);
    assert_eq!(seen.len(), 40);
    for w in seen.windows(2) {
        assert!(w[0] < w[1], "results out of order: {seen:?}");
    }
    assert_eq!(merged.frames(), 40);
    assert_eq!(report.per_worker.iter().map(|w| w.frames).sum::<u64>(), 40);
}

#[test]
fn tiny_reassembly_window_backpressures_instead_of_failing() {
    // Window of 1 with a worker stalled on frame 0: the dispatcher must
    // hold further dispatches (bounding the reassembler's out-of-order
    // buffer) and the run must still complete, in order — a skewed but
    // healthy run is never failed, it is backpressured.
    let mut cfg = test_cfg(2);
    cfg.reassembly_window = 1;
    let mut seen = Vec::new();
    let (report, _) = run(
        |_w| Ok(MockWorker::new(Behavior::StallFirst(Duration::from_millis(150)))),
        &cfg,
        20,
        |r| seen.push(r.frame_index),
    )
    .expect("a tiny window must backpressure, not fail");
    assert_eq!(report.frames, 20);
    assert_eq!(seen.len(), 20);
    for w in seen.windows(2) {
        assert!(w[0] < w[1], "results out of order: {seen:?}");
    }
}

#[test]
fn default_window_bounds_a_healthy_run() {
    // The auto-derived window is above the in-flight bound, so a healthy
    // uneven run never trips it.
    let cfg = test_cfg(3);
    assert!(cfg.effective_window() >= cfg.workers * cfg.queue_depth);
    let (report, _) = run(
        |_w| Ok(MockWorker::new(Behavior::Uneven(Duration::from_millis(1)))),
        &cfg,
        60,
        |_r| {},
    )
    .expect("healthy run under the default window");
    assert_eq!(report.frames, 60);
}

#[test]
fn worker_panic_fails_run_without_hanging() {
    let cfg = test_cfg(2);
    let t0 = Instant::now();
    let err = run(|_w| Ok(MockWorker::new(Behavior::PanicAt(5))), &cfg, 200, |_r| {})
        .expect_err("a panicking worker must fail the run");
    assert!(format!("{err:#}").contains("worker"), "{err:#}");
    assert!(t0.elapsed() < Duration::from_secs(30), "dispatcher hung after worker panic");
}

#[test]
fn worker_error_fails_run() {
    let cfg = test_cfg(2);
    let err = run(|_w| Ok(MockWorker::new(Behavior::ErrAt(3))), &cfg, 100, |_r| {})
        .expect_err("a failing worker must fail the run");
    assert!(format!("{err:#}").contains("failed"), "{err:#}");
}

#[test]
fn factory_failure_fails_run() {
    let cfg = test_cfg(2);
    let err = run(
        |w| -> Result<MockWorker> { bail!("no runtime for worker {w}") },
        &cfg,
        10,
        |_r| {},
    )
    .expect_err("a worker that cannot construct must fail the run");
    assert!(format!("{err:#}").contains("construction failed"), "{err:#}");
}
