//! Deterministic degraded-optics gate — fault- and drift-aware serving
//! proven under a step-controlled [`ManualClock`], with exact
//! expectations on routing, recal scheduling, and `accuracy_at_risk`
//! accounting:
//!
//! 1. **health-aware routing + the recal lifecycle**: with one worker
//!    accuracy-at-risk, the SLO session's (critical) frames route to the
//!    healthy worker, exactly the background frames ride the degraded
//!    optics, and when health sinks below `recal_below` the worker
//!    drains fully, pays the modeled recal cost over manual time, and
//!    rejoins healthy — the SLO session finishes with zero misses;
//! 2. **the health-blind control arm** (`HealthPolicy::aware = false`):
//!    the same machinery with awareness off serves the SLO frame on
//!    degraded-and-slow optics, provably missing the SLO — and never
//!    schedules a recal window even at floor health (degradation is
//!    recorded, not acted on);
//! 3. **availability beats accuracy**: a lone worker below the recal
//!    threshold is never drained (no healthy spare exists), keeps
//!    serving, and every frame counts accuracy-at-risk — per session,
//!    with the aggregate exactly the per-session sum;
//! 4. **end to end over the real substrate**: a seeded [`FaultPlan`] on
//!    the `sim` backend degrades both workers by pure thermal drift,
//!    the dispatcher recals them one at a time (at least one worker is
//!    always serving), and the session drains completely.
//!
//! Synchronization notes (same discipline as `rust/tests/qos.rs`): no
//! `thread::sleep` anywhere — blocking is channel receives and clock
//! events, and manual time moves only on explicit `advance` calls. The
//! only polling is yield-spin waits on `Server::stats()` snapshots for
//! *push-driven* worker-thread state (health publication, recal
//! transitions), bounded by generous wall-clock bailouts: those waits
//! are about scheduler liveness, never about manual time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use optovit::coordinator::batcher::{BatchPolicy, BucketRouter};
use optovit::coordinator::clock::{Clock, ManualClock};
use optovit::coordinator::engine::{EngineConfig, FrameWorker, HealthPolicy};
use optovit::coordinator::pipeline::{FrameResult, Pipeline, PipelineConfig};
use optovit::coordinator::server::{Server, ServerStats, SessionOptions};
use optovit::coordinator::stats::WorkerMode;
use optovit::coordinator::StageMetrics;
use optovit::photonics::AT_RISK_HEALTH;
use optovit::runtime::{
    AnyFactory, BackendFactory, BackendHealth, BackendKind, FaultPlan, HostConfig, RecalCost,
};
use optovit::sensor::{Frame, VideoSource};

const PATCH_PX: usize = 16;
/// Modeled recal window the mock backend charges (manual seconds).
const RECAL_S: f64 = 2.0;
/// Modeled recal energy the mock backend charges (joules).
const RECAL_J: f64 = 5.0;
/// Wall-clock bailout for yield-spin waits on push-driven worker state.
const SPIN_BOUND: Duration = Duration::from_secs(30);

/// Test-controlled fault state shared with one mock worker: the test
/// sets health and observes processing/recal activity through atomics.
struct Probe {
    /// Health score the worker's `health()` hook reports (f64 bits).
    health_bits: AtomicU64,
    /// Manual-clock milliseconds each processed group consumes — a
    /// degraded worker serves *slowly* (0 for a pristine one).
    stall_ms: AtomicU64,
    /// Process calls entered (counted before any gating), so the test
    /// can prove which worker a frame landed on.
    entered: AtomicU64,
    /// Backend recalibrations performed (each resets health to 1.0).
    recals: AtomicU64,
}

impl Probe {
    fn new(health: f64, stall_ms: u64) -> Arc<Self> {
        Arc::new(Probe {
            health_bits: AtomicU64::new(health.to_bits()),
            stall_ms: AtomicU64::new(stall_ms),
            entered: AtomicU64::new(0),
            recals: AtomicU64::new(0),
        })
    }

    fn set_health(&self, h: f64) {
        self.health_bits.store(h.to_bits(), Ordering::SeqCst);
    }

    fn health(&self) -> f64 {
        f64::from_bits(self.health_bits.load(Ordering::SeqCst))
    }

    fn entered(&self) -> u64 {
        self.entered.load(Ordering::SeqCst)
    }
}

/// Deterministic worker whose optical condition the test scripts: health
/// comes from its [`Probe`], an optional gate parks `process` until the
/// test sends a permit (one permit == one processed group), and a
/// nonzero stall advances the manual clock while "serving" — degraded
/// optics made exactly as slow as the test needs.
struct FaultableWorker {
    probe: Arc<Probe>,
    gate: Option<mpsc::Receiver<()>>,
    manual: ManualClock,
    router: BucketRouter,
    metrics: StageMetrics,
}

impl FaultableWorker {
    fn new(probe: Arc<Probe>, gate: Option<mpsc::Receiver<()>>, manual: ManualClock) -> Self {
        FaultableWorker {
            probe,
            gate,
            manual,
            router: BucketRouter::even(36, 4),
            metrics: StageMetrics::new(),
        }
    }

    /// Entry bookkeeping shared by `process` and `process_batch`: count
    /// the call, wait for a permit if gated, then burn the scripted
    /// amount of manual time.
    fn step(&mut self) {
        self.probe.entered.fetch_add(1, Ordering::SeqCst);
        if let Some(gate) = self.gate.take() {
            // A dropped sender means the test stopped choreographing;
            // degrade to ungated instead of wedging the worker.
            if gate.recv().is_ok() {
                self.gate = Some(gate);
            }
        }
        let stall = self.probe.stall_ms.load(Ordering::SeqCst);
        if stall > 0 {
            self.manual.advance(Duration::from_millis(stall));
        }
    }

    fn result(&mut self, frame: &Frame, batch_size: usize) -> FrameResult {
        let mask = frame.gt_mask(PATCH_PX);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        self.metrics.record_stage("total", 1e-4);
        self.metrics.record_frame(1e-5, kept);
        self.metrics.record_batch_size(batch_size);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: 1e-4,
            modeled_queueing_s: 0.0,
            batch_size,
            tier: optovit::quant::PrecisionTier::Int8,
            fp32_agreement: None,
        }
    }
}

impl FrameWorker for FaultableWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        self.step();
        Ok(self.result(frame, 1))
    }

    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        self.step();
        let n = frames.len().max(1);
        Ok(frames.iter().map(|f| self.result(f, n)).collect())
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }

    fn health(&mut self) -> Option<BackendHealth> {
        let h = self.probe.health();
        Some(BackendHealth {
            health: h,
            drift_nm: 0.0,
            stuck_cells: 0,
            dead_lanes: 0,
            at_risk: h < AT_RISK_HEALTH,
        })
    }

    fn recalibrate(&mut self) -> Option<RecalCost> {
        self.probe.recals.fetch_add(1, Ordering::SeqCst);
        self.probe.set_health(1.0);
        self.probe.stall_ms.store(0, Ordering::SeqCst);
        Some(RecalCost { time_s: RECAL_S, energy_j: RECAL_J })
    }
}

/// A manual-clock server over scripted [`FaultableWorker`]s, one probe
/// (and optional processing gate) per worker. `max_batch = 1` keeps
/// every frame its own group, so one gate permit releases exactly one
/// frame.
fn faulty_server(
    probes: Vec<Arc<Probe>>,
    gates: Vec<Option<mpsc::Receiver<()>>>,
    policy: HealthPolicy,
) -> (Server, ManualClock) {
    let (clock, manual) = Clock::manual();
    let mut cfg = EngineConfig::new(probes.len(), PATCH_PX, 96);
    cfg.clock = clock;
    cfg.batch = BatchPolicy::batched(1, Duration::from_secs(3600));
    // Manual time never advances past these on its own; generous bounds
    // keep test-driven advances from tripping them.
    cfg.warmup_timeout_s = 24.0 * 3600.0;
    cfg.stall_timeout_s = 24.0 * 3600.0;
    cfg.health = policy;
    let gates = Mutex::new(gates);
    let worker_clock = manual.clone();
    let server = Server::start(
        move |wid| {
            Ok(FaultableWorker::new(
                probes[wid].clone(),
                gates.lock().unwrap()[wid].take(),
                worker_clock.clone(),
            ))
        },
        cfg,
    )
    .expect("server");
    server.wait_ready(Duration::from_secs(3600)).expect("workers warm");
    (server, manual)
}

/// Identical frame content with distinct indices (see `qos.rs`): routing
/// depends only on policy, never on scene content.
fn frames(n: u64) -> Vec<Frame> {
    let template = VideoSource::new(96, 2, 42).next_frame();
    (0..n)
        .map(|i| {
            let mut f = template.clone();
            f.index = i;
            f
        })
        .collect()
}

/// Yield-spin until `pred` holds on a fresh stats snapshot — push-driven
/// worker state only (see the module doc), with a loud wall-clock
/// bailout.
fn wait_stats(server: &Server, what: &str, pred: impl Fn(&ServerStats) -> bool) -> ServerStats {
    let deadline = std::time::Instant::now() + SPIN_BOUND;
    loop {
        let stats = server.stats().expect("stats");
        if pred(&stats) {
            return stats;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "timed out waiting for {what}; worker health: {:?}",
            stats.worker_health
        );
        std::thread::yield_now();
    }
}

/// Yield-spin until a probe has entered `target` process calls.
fn wait_entered(probe: &Probe, target: u64, what: &str) {
    let deadline = std::time::Instant::now() + SPIN_BOUND;
    while probe.entered() < target {
        assert!(
            std::time::Instant::now() < deadline,
            "timed out waiting for {what} (entered {} of {target})",
            probe.entered()
        );
        std::thread::yield_now();
    }
}

/// Gate 1 — the aware arm. Worker 1 is accuracy-at-risk (health 0.65):
/// the SLO session's critical frame routes to healthy worker 0 even
/// though both are idle, exactly the two background frames ride the
/// degraded optics, and when health then collapses to 0.2 the worker
/// drains fully, pays a 2 s modeled recal window on the manual
/// timeline, and rejoins healthy. The SLO session never misses.
#[test]
fn health_aware_routing_shields_critical_traffic_and_recals_the_degraded_worker() {
    const SLO: Duration = Duration::from_millis(10);
    let (gate_tx, gate_rx) = mpsc::channel();
    let p0 = Probe::new(1.0, 0);
    // At risk (< AT_RISK_HEALTH = 0.75) but above recal_below (0.6):
    // routed around, not yet recalibrated.
    let p1 = Probe::new(0.65, 0);
    let (server, manual) = faulty_server(
        vec![p0.clone(), p1.clone()],
        vec![Some(gate_rx), None],
        HealthPolicy::default(),
    );

    // Routing reads published health: wait for both workers' first
    // publication before placing anything.
    wait_stats(&server, "initial health publication", |s| {
        s.worker_health.len() == 2
            && s.worker_health[0].updates >= 1
            && s.worker_health[1].at_risk
    });

    let mut slo = server
        .session(SessionOptions::named("slo").with_queue_depth(8).with_slo(SLO))
        .expect("slo session");
    let mut bulk =
        server.session(SessionOptions::named("bulk").with_queue_depth(8)).expect("bulk session");
    let mut fs = frames(8).into_iter();

    // The SLO frame is critical: both workers are idle, so only the
    // at-risk bias can explain it landing on worker 0 — where the gate
    // parks it mid-`process`, pinning worker 0's inflight at 1.
    slo.submit(fs.next().unwrap()).expect("slo submit");
    wait_entered(&p0, 1, "worker 0 to pick up the critical frame");

    // Background frames are non-critical and the degraded worker is now
    // the least loaded: exactly these two ride the at-risk optics.
    // Draining each result before the next submit keeps worker 1's
    // inflight observably 0 at every placement.
    for _ in 0..2 {
        bulk.submit(fs.next().unwrap()).expect("bulk submit");
        (&mut bulk).next().expect("bulk result").expect("bulk ok");
    }
    assert_eq!(p1.entered(), 2, "both background frames must land on the degraded worker");

    // Release the critical frame. No manual time ever passed, so the
    // SLO session emits at zero latency — no miss is possible.
    gate_tx.send(()).expect("release worker 0");
    (&mut slo).next().expect("slo result").expect("slo ok");

    // The optics now decay past the recal threshold. A 1 ms advance
    // (nothing is in flight) wakes the fleet: worker 1 republishes, the
    // dispatcher drains it, and — already idle — it starts its modeled
    // recal window immediately.
    p1.set_health(0.2);
    manual.advance(Duration::from_millis(1));
    let stats = wait_stats(&server, "worker 1 to enter its recal window", |s| {
        s.worker_health[1].mode == WorkerMode::Recalibrating
    });
    assert_eq!(stats.worker_health[1].recals, 0, "the recal window has not completed yet");
    assert!(
        (stats.worker_health[1].recal_energy_j - RECAL_J).abs() < 1e-12,
        "modeled recal energy is charged when the window opens (got {})",
        stats.worker_health[1].recal_energy_j
    );
    assert_eq!(p1.recals.load(Ordering::SeqCst), 1, "the backend recalibrated exactly once");

    // Drain-before-rejoin: a recalibrating worker is out of rotation,
    // so background traffic falls to worker 0 (permit sent first).
    gate_tx.send(()).expect("permit for worker 0");
    bulk.submit(fs.next().unwrap()).expect("bulk submit during recal");
    (&mut bulk).next().expect("bulk result").expect("bulk ok");
    assert_eq!(p1.entered(), 2, "a recalibrating worker must receive no frames");

    // The window is RECAL_S = 2 s of manual time: 1 s in, still closed…
    manual.advance(Duration::from_secs(1));
    let stats = server.stats().expect("stats");
    assert_eq!(stats.worker_health[1].mode, WorkerMode::Recalibrating);
    assert_eq!(stats.worker_health[1].recals, 0);

    // …and crossing it rejoins the worker, healthy.
    manual.advance(Duration::from_millis(1500));
    wait_stats(&server, "worker 1 to rejoin after recal", |s| {
        s.worker_health[1].recals == 1 && s.worker_health[1].mode == WorkerMode::Serving
    });

    // Serving continues on the healed fleet (either worker may take
    // this one — both are healthy now, so nothing is at risk).
    gate_tx.send(()).expect("permit for worker 0");
    bulk.submit(fs.next().unwrap()).expect("bulk submit after recal");
    (&mut bulk).next().expect("bulk result").expect("bulk ok");

    slo.close();
    bulk.close();
    let slo_report = slo.finish().expect("slo drain");
    let bulk_report = bulk.finish().expect("bulk drain");
    assert_eq!(slo_report.frames, 1);
    assert_eq!(slo_report.slo_miss, 0, "the critical session never touched degraded optics");
    assert_eq!(slo_report.accuracy_at_risk, 0);
    assert!(
        slo_report.p99_latency_s <= SLO.as_secs_f64(),
        "SLO p99 must hold (got {})",
        slo_report.p99_latency_s
    );
    assert_eq!(bulk_report.frames, 4);
    assert_eq!(
        bulk_report.accuracy_at_risk, 2,
        "exactly the two frames served at health 0.65 count as at risk"
    );

    let stats = server.stats().expect("stats");
    let session_sum: u64 = stats.sessions.iter().map(|s| s.report.accuracy_at_risk).sum();
    assert_eq!(session_sum, 2);
    assert_eq!(
        stats.aggregate.accuracy_at_risk, session_sum,
        "aggregate accuracy_at_risk must equal the per-session sum"
    );

    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, 5);
    assert_eq!(agg.slo_miss, 0);
    assert_eq!(agg.accuracy_at_risk, 2);
    let w0 = agg.per_worker.iter().find(|w| w.worker == 0).expect("worker 0 stats");
    let w1 = agg.per_worker.iter().find(|w| w.worker == 1).expect("worker 1 stats");
    assert_eq!(w1.recals, 1);
    assert_eq!(w1.at_risk_frames, 2);
    assert!((w1.health - 1.0).abs() < 1e-12, "the degraded worker rejoined at full health");
    assert_eq!(w0.recals, 0);
    assert_eq!(w0.at_risk_frames, 0);
    assert_eq!(w0.frames + w1.frames, 5);
}

/// Gate 2 — the control arm. Awareness off, both workers degraded
/// (health 0.2) and slow: serving any group burns 50 ms of manual time,
/// five times the SLO. The blind dispatcher serves the SLO frame on
/// degraded optics and provably misses — and even at floor health it
/// never schedules a recal window (degradation recorded, not acted on).
#[test]
fn health_blind_control_misses_slo_on_degraded_optics_and_never_recals() {
    const SLO: Duration = Duration::from_millis(10);
    let p0 = Probe::new(0.2, 50);
    let p1 = Probe::new(0.2, 50);
    let blind = HealthPolicy { aware: false, ..HealthPolicy::default() };
    let (server, _manual) = faulty_server(vec![p0.clone(), p1.clone()], vec![None, None], blind);

    wait_stats(&server, "initial health publication", |s| {
        s.worker_health.iter().all(|w| w.updates >= 1 && w.at_risk)
    });

    let mut slo = server
        .session(SessionOptions::named("slo").with_queue_depth(8).with_slo(SLO))
        .expect("slo session");
    slo.submit(frames(1).remove(0)).expect("submit");
    (&mut slo).next().expect("result").expect("ok");

    slo.close();
    let report = slo.finish().expect("drain");
    assert_eq!(report.frames, 1);
    assert_eq!(
        report.slo_miss, 1,
        "a health-blind dispatcher serves the SLO frame on degraded optics and misses"
    );
    assert_eq!(report.accuracy_at_risk, 1, "…and the frame counts as accuracy-at-risk");

    let stats = server.stats().expect("stats");
    assert_eq!(stats.aggregate.accuracy_at_risk, 1);
    let session_sum: u64 = stats.sessions.iter().map(|s| s.report.accuracy_at_risk).sum();
    assert_eq!(stats.aggregate.accuracy_at_risk, session_sum);
    for w in &stats.worker_health {
        assert_eq!(w.mode, WorkerMode::Serving, "blind mode never schedules a recal window");
        assert_eq!(w.recals, 0);
    }
    assert_eq!(p0.recals.load(Ordering::SeqCst) + p1.recals.load(Ordering::SeqCst), 0);

    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.slo_miss, 1);
    assert_eq!(agg.accuracy_at_risk, 1);
    let risky: u64 = agg.per_worker.iter().map(|w| w.at_risk_frames).sum();
    assert_eq!(risky, 1);
    assert!(
        agg.per_worker.iter().all(|w| w.health < AT_RISK_HEALTH),
        "degradation must still be recorded when not acted on"
    );
}

/// Gate 3 — availability beats accuracy. A lone worker below the recal
/// threshold is never drained (draining it would leave nobody serving);
/// it keeps serving with every frame counted accuracy-at-risk, per
/// session, and the aggregate is exactly the per-session sum.
#[test]
fn lone_degraded_worker_keeps_serving_and_risk_counts_per_session() {
    // Below recal_below (0.6) — would be drained if a spare existed.
    let p0 = Probe::new(0.5, 0);
    let (server, _manual) = faulty_server(vec![p0.clone()], vec![None], HealthPolicy::default());
    wait_stats(&server, "health publication", |s| s.worker_health[0].updates >= 1);

    let mut cam_a =
        server.session(SessionOptions::named("cam-a").with_queue_depth(8)).expect("cam-a");
    let mut cam_b =
        server.session(SessionOptions::named("cam-b").with_queue_depth(8)).expect("cam-b");
    for f in frames(2) {
        cam_a.submit(f).expect("a submit");
    }
    for f in frames(3) {
        cam_b.submit(f).expect("b submit");
    }
    for _ in 0..2 {
        (&mut cam_a).next().expect("a result").expect("a ok");
    }
    for _ in 0..3 {
        (&mut cam_b).next().expect("b result").expect("b ok");
    }

    // Five frames served through dispatcher sweeps that saw health 0.5
    // the whole time — and still no drain was scheduled.
    let stats = server.stats().expect("stats");
    assert_eq!(stats.worker_health[0].mode, WorkerMode::Serving);
    assert_eq!(stats.worker_health[0].recals, 0);
    assert_eq!(stats.worker_health[0].at_risk_frames, 5);
    let session_sum: u64 = stats.sessions.iter().map(|s| s.report.accuracy_at_risk).sum();
    assert_eq!(session_sum, 5);
    assert_eq!(stats.aggregate.accuracy_at_risk, session_sum);

    cam_a.close();
    cam_b.close();
    let report_a = cam_a.finish().expect("a drain");
    let report_b = cam_b.finish().expect("b drain");
    assert_eq!(report_a.accuracy_at_risk, 2);
    assert_eq!(report_b.accuracy_at_risk, 3);
    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, 5);
    assert_eq!(agg.accuracy_at_risk, 5);
    assert_eq!(agg.per_worker[0].at_risk_frames, 5);
    assert_eq!(agg.per_worker[0].recals, 0);
}

/// Gate 4 — end to end over the real substrate: a seeded [`FaultPlan`]
/// on the `sim` backend, driven by the serving clock. At zero elapsed
/// manual time the optics are pristine (no frame is at risk); 600 s of
/// thermal drift at 1e-3 nm/s floors both workers' health, after which
/// the dispatcher recals them one at a time (at least one worker always
/// keeps serving) with modeled energy charged, and the session drains
/// completely.
#[test]
fn sim_fault_plan_degrades_and_recals_end_to_end() {
    let (clock, manual) = Clock::manual();
    let mut ecfg = EngineConfig::new(2, PATCH_PX, 96);
    ecfg.clock = clock.clone();
    ecfg.batch = BatchPolicy::batched(1, Duration::from_secs(3600));
    ecfg.warmup_timeout_s = 24.0 * 3600.0;
    ecfg.stall_timeout_s = 24.0 * 3600.0;
    let pipe_cfg = PipelineConfig::tiny_96();
    let mut factory = AnyFactory::new(BackendKind::Sim, "unused-artifacts")
        .with_faults(FaultPlan { seed: 5, drift_nm_per_s: 1e-3, clock: clock.clone() });
    // One encoder block keeps debug-mode forwards cheap (as in
    // `sessions.rs`), head width in lockstep with the pipeline's.
    factory.host = HostConfig { depth_limit: Some(1), ..HostConfig::default() };
    factory.host.num_classes = pipe_cfg.num_classes;
    let server = {
        let cfg = pipe_cfg.clone();
        Server::start(move |wid| Pipeline::with_backend(cfg.clone(), factory.create(wid)?), ecfg)
            .expect("server")
    };
    server.wait_ready(Duration::from_secs(3600)).expect("workers warm");

    let mut cam = server.session(SessionOptions::named("cam").with_queue_depth(8)).expect("cam");
    for f in frames(3) {
        cam.submit(f).expect("submit");
    }
    for _ in 0..3 {
        (&mut cam).next().expect("result").expect("ok");
    }
    assert_eq!(
        cam.report().accuracy_at_risk,
        0,
        "no manual time has passed, so the optics are still pristine"
    );

    // 600 s of drift floors both workers. Step manual time in 500 ms
    // increments until each has paid at least one recal window — drift
    // re-accrues between recals (~5e-4 nm per step), so health may
    // oscillate; the recal *count* is monotone and must reach every
    // worker because the dispatcher drains only while a serving spare
    // exists.
    manual.advance(Duration::from_secs(600));
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let stats = server.stats().expect("stats");
        if stats.worker_health.iter().all(|w| w.recals >= 1) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "fleet never recalibrated; worker health: {:?}",
            stats.worker_health
        );
        manual.advance(Duration::from_millis(500));
        std::thread::yield_now();
    }
    let stats = server.stats().expect("stats");
    for w in &stats.worker_health {
        assert!(w.recal_energy_j > 0.0, "modeled recal energy must be charged: {w:?}");
    }

    // The fleet serves on: two more frames drain through whatever
    // workers are in rotation (at least one always is).
    for (i, mut f) in frames(2).into_iter().enumerate() {
        f.index = 3 + i as u64;
        cam.submit(f).expect("submit after degradation");
    }
    for _ in 0..2 {
        (&mut cam).next().expect("result").expect("ok");
    }
    cam.close();
    let report = cam.finish().expect("drain");
    assert_eq!(report.frames, 5);

    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, 5);
    assert!(agg.per_worker.iter().all(|w| w.recals >= 1));
}
