//! Asserts the acceptance criterion of the zero-allocation frame hot path:
//! steady-state frames perform **zero heap allocations before each backend
//! call**. The counted region is exactly the host-side work
//! `Pipeline::process_frame` does between receiving a frame and handing
//! `TensorRef` views to the execution backend — patchify, score adoption +
//! mask thresholding, and bucket routing/staging — all through the shared
//! `FrameScratch` code the pipeline itself uses. (The full-frame bound
//! over a live backend is asserted in `host_backend.rs`.)
//!
//! This binary installs the counting allocator process-wide and holds a
//! single test, so the counter sees only the hot path.

use optovit::coordinator::pipeline::FrameScratch;
use optovit::coordinator::BucketRouter;
use optovit::roi::PatchMask;
use optovit::sensor::VideoSource;
use optovit::util::bench::{count_allocations, CountingAlloc};
use optovit::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const PATCH_DIM: usize = 16 * 16 * 3;

fn fill_scores(scratch: &FrameScratch, scores: &mut [f32]) {
    for (p, s) in scores.iter_mut().enumerate() {
        let row = &scratch.patches()[p * PATCH_DIM..(p + 1) * PATCH_DIM];
        *s = row.iter().sum::<f32>() / PATCH_DIM as f32 - 0.35;
    }
}

#[test]
fn steady_state_host_stages_do_not_allocate() {
    let mut src = VideoSource::new(96, 2, 42);
    let router = BucketRouter::even(36, 4);
    // A router whose largest bucket is below the full patch count forces
    // the sort/truncate route branch, which must also be alloc-free.
    let clamped = BucketRouter::new(vec![9, 18]);
    let mut scratch = FrameScratch::new(36, PATCH_DIM, 36);
    let mut scores = vec![0.0f32; 36];
    // The masked gather path (`gather_patches_into`) must also be
    // alloc-free once its destination buffer is warm: the old
    // implementation leaked a fresh index Vec per call.
    let mask = PatchMask::random(6, 0.4, &mut Rng::new(7));
    let mut gathered = Vec::new();

    // Warm-up frame: buffers reach steady-state capacity.
    let warm = src.next_frame();
    scratch.stage_patchify(&warm, 16);
    fill_scores(&scratch, &mut scores);
    scratch.stage_mask(6, &scores, 0.5);
    scratch.stage_route(&router, PATCH_DIM);
    scratch.stage_mask_full(6);
    scratch.stage_route(&clamped, PATCH_DIM);
    mask.gather_patches_into(scratch.patches(), PATCH_DIM, &mut gathered);

    for _ in 0..5 {
        let frame = src.next_frame();
        let (_, allocs) = count_allocations(|| {
            // Masked path: patchify → mask from scores → route/stage.
            scratch.stage_patchify(&frame, 16);
            fill_scores(&scratch, &mut scores);
            scratch.stage_mask(6, &scores, 0.5);
            let bucket = scratch.stage_route(&router, PATCH_DIM);
            std::hint::black_box(scratch.bucket_patches(bucket, PATCH_DIM).len());
            // Unmasked path + over-full clamped routing (sort/truncate).
            scratch.stage_mask_full(6);
            let b2 = scratch.stage_route(&clamped, PATCH_DIM);
            std::hint::black_box(scratch.valid(b2).len());
            // Masked gather into the warmed caller buffer.
            mask.gather_patches_into(scratch.patches(), PATCH_DIM, &mut gathered);
            std::hint::black_box(gathered.len());
        });
        assert_eq!(allocs, 0, "steady-state hot path touched the heap");
    }
}
