//! Session-serving gates over one shared server — the multi-tenant
//! contract of `coordinator::server`:
//!
//! 1. two sessions over one 2-worker server deliver **per-session
//!    in-order** results and **amortize cross-session**: same-bucket
//!    frames from different cameras ride one bucket-major micro-batch
//!    (`mean_batch > 1` per session), with aggregate-vs-per-session frame
//!    accounting consistent;
//! 2. **fair admission**: a hot session with a deep backlog cannot starve
//!    a late, small session (weighted round-robin dequeue);
//! 3. **graceful mid-flight teardown**: dropping a session with frames
//!    queued and in flight cancels it without panicking the server or
//!    disturbing its neighbours (the unwrap-hardening regression test).
//!
//! Pipeline-backed tests run on the artifact-free host backend, so CI
//! gates all of this with no Python and no compiled HLO.
//!
//! Wall-clock audit (the qos/clock PR): sleeps in this file are never
//! used as *synchronization* — every assertion is completion-based. The
//! fairness test is driven by an explicit permit channel (one permit ==
//! one processed frame), so its starvation assertion is deterministic
//! rather than a race against a sleeping worker; `SlowWorker`'s 2 ms
//! sleep in the teardown test only keeps frames in flight long enough to
//! make the mid-flight drop meaningful (its assertions hold at any
//! speed); and the cross-session batching test's lane deadline is
//! generous because it is a *liveness* bound (flush leftovers), not a
//! timing assumption.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::{EngineConfig, FrameWorker};
use optovit::coordinator::pipeline::{FrameResult, Pipeline, PipelineConfig};
use optovit::coordinator::server::{Server, SessionOptions};
use optovit::coordinator::{BucketRouter, StageMetrics};
use optovit::runtime::{HostBackend, HostConfig};
use optovit::sensor::{Frame, VideoSource};

const PATCH_PX: usize = 16;

/// One encoder block keeps debug-mode forwards cheap while exercising the
/// full dataflow (embed → masked attention → FFN → head).
fn host_cfg() -> HostConfig {
    HostConfig { depth_limit: Some(1), ..HostConfig::default() }
}

fn engine_cfg(workers: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new(workers, PATCH_PX, 96);
    cfg.warmup_timeout_s = 60.0;
    cfg.stall_timeout_s = 30.0;
    cfg
}

/// Deterministic stand-in worker with a fixed per-frame latency: routes
/// from the ground-truth mask, like the engine tests' mock.
struct SlowWorker {
    delay: Duration,
    router: BucketRouter,
    metrics: StageMetrics,
}

impl SlowWorker {
    fn new(delay: Duration) -> Self {
        SlowWorker { delay, router: BucketRouter::even(36, 4), metrics: StageMetrics::new() }
    }
}

impl FrameWorker for SlowWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        std::thread::sleep(self.delay);
        let mask = frame.gt_mask(PATCH_PX);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        self.metrics.record_stage("total", self.delay.as_secs_f64());
        self.metrics.record_frame(1e-5, kept);
        self.metrics.record_batch_size(1);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: self.delay.as_secs_f64(),
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: optovit::quant::PrecisionTier::Int8,
            fp32_agreement: None,
        })
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }
}

/// Two cameras over one 2-worker server: per-session in-order delivery,
/// cross-session same-bucket batch amortization, and per-session vs
/// aggregate frame accounting.
#[test]
fn two_sessions_amortize_one_bucket_major_batcher() {
    const FRAMES_PER_SESSION: u64 = 6;
    let mut ecfg = engine_cfg(2);
    // A generous lane deadline: every frame is pre-submitted, so groups
    // fill by count; the deadline only flushes trailing partial groups
    // (a liveness bound — 2 s keeps it safe under heavily parallel CI).
    ecfg.batch = BatchPolicy::batched(4, Duration::from_secs(2));
    let pipe_cfg = PipelineConfig::tiny_96();
    let server = {
        let cfg = pipe_cfg.clone();
        Server::start(
            move |_wid| Pipeline::with_backend(cfg.clone(), HostBackend::new(host_cfg())),
            ecfg,
        )
        .expect("server")
    };
    let mut cam_a = server
        .session(SessionOptions::named("cam-a").with_queue_depth(16))
        .expect("session a");
    let mut cam_b = server
        .session(SessionOptions::named("cam-b").with_queue_depth(16))
        .expect("session b");

    // Identical frame content from both cameras → every submission routes
    // to the same bucket, so amortization *must* happen if the lanes are
    // truly shared across sessions. Distinct indices keep order checkable.
    let template = VideoSource::new(96, 2, 42).next_frame();
    for i in 0..FRAMES_PER_SESSION {
        let mut fa = template.clone();
        fa.index = i;
        cam_a.submit(fa).expect("submit a");
        let mut fb = template.clone();
        fb.index = i;
        cam_b.submit(fb).expect("submit b");
    }
    cam_a.close();
    cam_b.close();

    let mut order_a = Vec::new();
    for item in &mut cam_a {
        order_a.push(item.expect("cam-a result").frame_index);
    }
    let report_a = cam_a.report();
    let mut order_b = Vec::new();
    for item in &mut cam_b {
        order_b.push(item.expect("cam-b result").frame_index);
    }
    let report_b = cam_b.report();

    assert_eq!(order_a.len() as u64, FRAMES_PER_SESSION);
    assert_eq!(order_b.len() as u64, FRAMES_PER_SESSION);
    for pair in order_a.windows(2) {
        assert!(pair[0] < pair[1], "cam-a emitted out of order: {order_a:?}");
    }
    for pair in order_b.windows(2) {
        assert!(pair[0] < pair[1], "cam-b emitted out of order: {order_b:?}");
    }
    // Cross-session bucket-major amortization: with every frame in one
    // bucket and both sessions feeding the same lanes, each session's
    // frames must (on average) have shared their dispatch.
    assert!(
        report_a.mean_batch > 1.0,
        "cam-a frames never shared a batch (mean_batch {})",
        report_a.mean_batch
    );
    assert!(
        report_b.mean_batch > 1.0,
        "cam-b frames never shared a batch (mean_batch {})",
        report_b.mean_batch
    );
    assert_eq!(report_a.frames, FRAMES_PER_SESSION);
    assert_eq!(report_b.frames, FRAMES_PER_SESSION);

    // Aggregate-vs-per-session accounting, live and terminal.
    drop(cam_a);
    drop(cam_b);
    let stats = server.stats().expect("stats");
    assert_eq!(stats.sessions.len(), 2);
    let session_sum: u64 = stats.sessions.iter().map(|s| s.report.frames).sum();
    assert_eq!(session_sum, 2 * FRAMES_PER_SESSION);
    assert_eq!(stats.aggregate.frames, session_sum, "aggregate must equal the session sum");
    assert!(stats.sessions.iter().all(|s| s.complete && !s.canceled));
    let (agg, merged) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, 2 * FRAMES_PER_SESSION);
    assert_eq!(merged.frames(), 2 * FRAMES_PER_SESSION);
    assert_eq!(agg.backend, "host");
    assert!(agg.mean_batch > 1.0, "merged metrics must record the shared batches");
}

/// Worker gated by an explicit permit channel: each `process` call
/// consumes one permit (blocking on the channel — a completion signal,
/// not a sleep) and reports the processed frame's index back to the
/// test, so the test observes the dispatcher's admission order in
/// deterministic lockstep. Dropping the permit sender free-runs the
/// worker.
struct GateWorker {
    permits: mpsc::Receiver<()>,
    done: mpsc::Sender<u64>,
    router: BucketRouter,
    metrics: StageMetrics,
}

impl FrameWorker for GateWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        // Blocks until the test grants a permit; a closed channel means
        // the gated phase is over — process freely.
        let _ = self.permits.recv();
        let mask = frame.gt_mask(PATCH_PX);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        self.metrics.record_stage("total", 1e-4);
        self.metrics.record_frame(1e-5, kept);
        self.metrics.record_batch_size(1);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        let result = FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: 1e-4,
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: optovit::quant::PrecisionTier::Int8,
            fp32_agreement: None,
        };
        self.done.send(frame.index).ok();
        Ok(result)
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }
}

/// Fair admission: a hot session that floods 40 frames before a cold
/// session submits 8 must not starve it — weighted round-robin dequeue
/// interleaves the cold frames. Ported off wall-clock pacing (the worker
/// used to sleep 2 ms per frame and the test raced it): the worker is now
/// gated by permits, so "the cold session finished while the hot backlog
/// was still draining" is observed in lockstep, not inferred from timing.
#[test]
fn hot_session_cannot_starve_a_cold_one() {
    const HOT: u64 = 40;
    const COLD: u64 = 8;
    const COLD_TAG: u64 = 10_000;
    let (permit_tx, permit_rx) = mpsc::channel::<()>();
    let (done_tx, done_rx) = mpsc::channel::<u64>();
    // Hand the channel ends to the single worker through the factory
    // (which must be callable repeatedly, hence the take-once cell).
    let gate = Arc::new(Mutex::new(Some((permit_rx, done_tx))));
    let server = Server::start(
        move |_wid| {
            let (permits, done) =
                gate.lock().unwrap().take().expect("one worker, one gate");
            Ok(GateWorker {
                permits,
                done,
                router: BucketRouter::even(36, 4),
                metrics: StageMetrics::new(),
            })
        },
        engine_cfg(1),
    )
    .expect("server");
    // Window 64 > HOT so the per-session dispatch window never binds:
    // only fair dequeue (not window backpressure) can keep the hot
    // backlog from finishing first.
    let hot = server
        .session(SessionOptions::named("hot").with_queue_depth(64).with_window(64))
        .expect("hot session");
    let mut cold = server
        .session(SessionOptions::named("cold").with_queue_depth(16))
        .expect("cold session");

    let mut src = VideoSource::new(96, 2, 7);
    for _ in 0..HOT {
        hot.submit(src.next_frame()).expect("hot submit");
    }
    for _ in 0..COLD {
        let mut f = src.next_frame();
        f.index += COLD_TAG; // tag cold frames for the done-channel ledger
        cold.submit(f).expect("cold submit");
    }
    cold.close();

    // Lockstep: one permit == one processed frame == one ledger entry.
    let mut processed_hot = 0u64;
    let mut processed_cold = 0u64;
    while processed_cold < COLD {
        permit_tx.send(()).expect("worker must be alive");
        let idx = done_rx.recv().expect("exactly one completion per permit");
        if idx >= COLD_TAG {
            processed_cold += 1;
        } else {
            processed_hot += 1;
        }
    }
    // At the moment the last cold frame was processed, the hot backlog
    // must not be done: FIFO admission would have served all 40 first.
    assert!(
        processed_hot < HOT,
        "cold session waited behind the whole hot backlog ({processed_hot} of {HOT} hot \
         frames processed at cold completion) — admission is not fair"
    );
    // Free-run the worker for the remainder.
    drop(permit_tx);

    let mut cold_order = Vec::new();
    for item in &mut cold {
        cold_order.push(item.expect("cold result").frame_index);
    }
    assert_eq!(cold_order.len() as u64, COLD, "every cold frame must be served");
    for pair in cold_order.windows(2) {
        assert!(pair[0] < pair[1], "cold emitted out of order: {cold_order:?}");
    }
    // The hot session still completes in full, in order.
    let hot_report = hot.finish().expect("hot drain");
    assert_eq!(hot_report.frames, HOT);
    let (agg, _merged) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, HOT + COLD);
}

/// Regression (unwrap hardening): dropping a session mid-flight — frames
/// still queued and in flight — must cancel it gracefully. No panic, no
/// poisoned lock, no stalled neighbour: the surviving session drains in
/// full and shutdown succeeds.
#[test]
fn dropping_a_session_mid_flight_is_graceful() {
    const DOOMED: u64 = 20;
    const SURVIVOR: u64 = 10;
    let server = Server::start(
        |_wid| Ok(SlowWorker::new(Duration::from_millis(2))),
        engine_cfg(2),
    )
    .expect("server");
    let doomed = server
        .session(SessionOptions::named("doomed").with_queue_depth(32))
        .expect("doomed session");
    let doomed_id = doomed.id();
    let mut survivor = server
        .session(SessionOptions::named("survivor").with_queue_depth(16))
        .expect("survivor session");

    let mut src = VideoSource::new(96, 2, 3);
    for _ in 0..DOOMED {
        doomed.submit(src.next_frame()).expect("doomed submit");
    }
    for _ in 0..SURVIVOR {
        survivor.submit(src.next_frame()).expect("survivor submit");
    }
    // Mid-flight teardown: the doomed session still has frames queued at
    // the dispatcher and results in flight from the workers.
    drop(doomed);

    survivor.close();
    let mut order = Vec::new();
    for item in &mut survivor {
        order.push(item.expect("survivor result").frame_index);
    }
    assert_eq!(order.len() as u64, SURVIVOR, "the surviving session must drain in full");
    for pair in order.windows(2) {
        assert!(pair[0] < pair[1], "survivor emitted out of order: {order:?}");
    }
    let survivor_report = survivor.report();
    assert_eq!(survivor_report.frames, SURVIVOR);
    drop(survivor);

    let stats = server.stats().expect("stats must stay readable after a canceled session");
    let doomed_row =
        stats.sessions.iter().find(|s| s.id == doomed_id).expect("doomed session row");
    assert!(doomed_row.canceled, "the dropped session must be marked canceled");
    assert!(
        doomed_row.report.frames <= DOOMED,
        "a canceled session never accounts more than it submitted"
    );
    let session_sum: u64 = stats.sessions.iter().map(|s| s.report.frames).sum();
    assert_eq!(
        stats.aggregate.frames, session_sum,
        "aggregate accounting must stay consistent after a mid-flight cancel"
    );
    // The server survives: graceful shutdown, no panic surfaced as error.
    let (agg, _merged) = server.shutdown().expect("shutdown after mid-flight session drop");
    assert!(agg.frames >= SURVIVOR);
}
