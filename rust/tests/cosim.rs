//! Deterministic co-sim gate — the discrete-event queueing simulator's
//! correctness anchors and its wiring through the serving stack, with
//! **exact** (bitwise where stated) expectations:
//!
//! 1. zero-load anchor: with every frame arriving at t = 0, the DES
//!    replay's steady-state completion spacing equals the closed-form
//!    [`AttentionSchedule::steady_state_frame_ns`] **bitwise**, and a
//!    frame arriving to idle hardware reports queueing of exactly `0.0`;
//! 2. load sensitivity: modeled p99 latency is **strictly** increasing
//!    across an offered-load sweep under seeded-Poisson arrivals;
//! 3. determinism: the same arrival trace replays to bit-identical
//!    spans, the same operating point to a bit-identical report, and
//!    the same paced serving pipeline to bit-identical per-frame
//!    queueing — there is no hidden wall-clock or RNG state;
//! 4. accounting: served through real sim-backend pipelines with the
//!    co-sim armed, the aggregate `modeled_queueing_s` equals the
//!    per-session sum **exactly**, and is positive under a dense paced
//!    arrival process.

use optovit::arch::scheduler::AttentionSchedule;
use optovit::arch::CoreParams;
use optovit::coordinator::clock::Clock;
use optovit::coordinator::engine::EngineConfig;
use optovit::coordinator::pipeline::{Pipeline, PipelineConfig};
use optovit::coordinator::server::{Server, SessionOptions};
use optovit::cosim::{simulate, OperatingPoint, QueueSim};
use optovit::runtime::{AnyFactory, BackendFactory, BackendKind, QueueingPlan};
use optovit::sensor::VideoSource;
use optovit::vit::{VitConfig, VitVariant};

const TOKENS: usize = 18;

fn tiny() -> VitConfig {
    VitConfig::variant(VitVariant::Tiny, 96, 10)
}

/// A sim-backend factory with the queueing co-sim armed. `pace_fps`
/// paces virtual arrivals (deterministic regardless of wall time);
/// artifact dir is irrelevant — the sim backend runs artifact-free.
fn cosim_factory(pace_fps: f64) -> AnyFactory {
    let cfg = PipelineConfig::tiny_96();
    let mut factory = AnyFactory::new(BackendKind::Sim, "artifacts".to_string());
    factory.host.num_classes = cfg.num_classes;
    factory.with_queueing(QueueingPlan {
        cores: 5,
        pace_fps: Some(pace_fps),
        clock: Clock::system(),
    })
}

/// Gate 1a: back-to-back arrivals at t = 0 drive the pipeline to steady
/// state, and the completion spacing there equals the closed-form
/// schedule horizon delta bitwise — the DES is the schedule, replayed.
#[test]
fn zero_load_replay_matches_closed_form_bitwise() {
    let cfg = tiny();
    let params = CoreParams::default();
    let steady = AttentionSchedule::steady_state_frame_ns(&cfg, TOKENS, params, true);
    let mut sim = QueueSim::new(cfg, params);
    let c1 = sim.arrive(0.0, TOKENS).completion_ns;
    let c2 = sim.arrive(0.0, TOKENS).completion_ns;
    let c3 = sim.arrive(0.0, TOKENS).completion_ns;
    assert_eq!(c2 - c1, steady, "steady-state spacing must equal the closed form bitwise");
    assert_eq!(c3 - c2, steady, "and stay there for every further frame");
}

/// Gate 1b: a frame arriving to idle hardware waits exactly `0.0` ns —
/// not a float residue — no matter how much history the simulator has.
#[test]
fn idle_arrivals_report_exactly_zero_queueing() {
    let cfg = tiny();
    let mut sim = QueueSim::new(cfg, CoreParams::default());
    let first = sim.arrive(0.0, TOKENS);
    assert_eq!(first.queueing_ns, 0.0, "an empty simulator cannot charge waiting");
    // Far past the first frame's completion: hardware is idle again.
    let mut t = first.completion_ns;
    for _ in 0..5 {
        t += 10.0 * first.service_ns;
        let span = sim.arrive(t, TOKENS);
        assert_eq!(span.queueing_ns, 0.0, "idle-arrival queueing must be exactly zero");
        assert_eq!(
            span.latency_ns(),
            span.service_ns,
            "an unqueued frame's latency is pure service"
        );
        t = span.completion_ns;
    }
}

/// Gate 2: p99 modeled latency is strictly increasing across an
/// offered-load sweep — the load dependence the static latency cache
/// could never express, and the reason the co-sim exists.
#[test]
fn p99_latency_strictly_increases_with_offered_load() {
    let reports: Vec<_> = [0.4, 0.75, 0.95]
        .iter()
        .map(|&load| {
            simulate(
                &tiny(),
                &OperatingPoint {
                    cores: 5,
                    batch: 1,
                    load,
                    frames: 400,
                    n_tokens: TOKENS,
                    arrival_seed: Some(7),
                },
            )
        })
        .collect();
    for pair in reports.windows(2) {
        assert!(
            pair[1].p99_latency_ns > pair[0].p99_latency_ns,
            "p99 must strictly increase with load: {} !> {} (loads {} vs {})",
            pair[1].p99_latency_ns,
            pair[0].p99_latency_ns,
            pair[1].load,
            pair[0].load
        );
        assert!(
            pair[1].mean_queueing_ns > pair[0].mean_queueing_ns,
            "mean queueing must strictly increase with load"
        );
    }
}

/// Gate 3a: the same arrival trace replays bit-identically — spans, not
/// just summaries.
#[test]
fn same_trace_replays_bitwise() {
    let cfg = tiny();
    let trace: Vec<f64> = (0..64).map(|k| k as f64 * 700.0).collect();
    let run = || {
        let mut sim = QueueSim::new(cfg, CoreParams::default());
        trace.iter().map(|&t| sim.arrive(t, TOKENS)).collect::<Vec<_>>()
    };
    assert_eq!(run(), run(), "identical traces must produce identical spans");
    let a = simulate(
        &tiny(),
        &OperatingPoint {
            cores: 5,
            batch: 4,
            load: 0.8,
            frames: 200,
            n_tokens: TOKENS,
            arrival_seed: Some(11),
        },
    );
    let b = simulate(
        &tiny(),
        &OperatingPoint {
            cores: 5,
            batch: 4,
            load: 0.8,
            frames: 200,
            n_tokens: TOKENS,
            arrival_seed: Some(11),
        },
    );
    assert_eq!(a.p99_latency_ns, b.p99_latency_ns);
    assert_eq!(a.mean_queueing_ns, b.mean_queueing_ns);
    assert_eq!(a.achieved_kfps, b.achieved_kfps);
}

/// Gate 3b: the armed serving pipeline is deterministic end-to-end —
/// two identical paced runs report bit-identical per-frame queueing,
/// the first frame waits exactly zero, and dense followers all wait.
#[test]
fn paced_pipeline_queueing_is_deterministic_and_positive() {
    let run = || -> Vec<f64> {
        // 1 GHz offered arrivals: every follower lands on busy cores.
        let factory = cosim_factory(1e9);
        let mut p = Pipeline::with_backend(PipelineConfig::tiny_96(), factory.create(0).unwrap())
            .expect("pipeline");
        let mut src = VideoSource::new(96, 2, 42);
        (0..8).map(|_| p.process_frame(&src.next_frame()).unwrap().modeled_queueing_s).collect()
    };
    let a = run();
    assert_eq!(a[0], 0.0, "the first paced arrival lands on idle hardware");
    assert!(
        a.iter().skip(1).all(|&q| q > 0.0),
        "1 GHz arrivals must queue every follower: {a:?}"
    );
    assert_eq!(a, run(), "paced modeled queueing must be bit-deterministic");
}

/// Gate 4: per-session accounting. Two sessions served by a real
/// sim-backend worker with the co-sim armed: the aggregate
/// `modeled_queueing_s` equals the per-session sum **exactly** (both are
/// summed from the same per-session accumulators in registration
/// order), and dense paced arrivals make it positive.
#[test]
fn aggregate_queueing_is_exactly_the_per_session_sum() {
    let cfg = PipelineConfig::tiny_96();
    let factory = cosim_factory(1e9);
    let mut ecfg = EngineConfig::new(1, 16, 96);
    ecfg.warmup_timeout_s = 60.0;
    ecfg.stall_timeout_s = 30.0;
    let server = {
        let cfg = cfg.clone();
        Server::start(move |wid| Pipeline::with_backend(cfg.clone(), factory.create(wid)?), ecfg)
            .expect("server")
    };

    const PER_SESSION: u64 = 6;
    let mut reports = Vec::new();
    let mut sessions = Vec::new();
    for cam in 0..2u64 {
        sessions.push(
            server
                .session(SessionOptions::named(format!("cam-{cam}")).with_queue_depth(16))
                .expect("session"),
        );
    }
    for (cam, session) in sessions.iter_mut().enumerate() {
        let mut src = VideoSource::new(96, 2, 42 + cam as u64);
        for _ in 0..PER_SESSION {
            session.submit(src.next_frame()).expect("submit");
        }
    }
    for mut session in sessions {
        session.close();
        reports.push(session.finish().expect("drain"));
    }
    // Registration order — the same order both the live stats and the
    // terminal aggregate fold the per-session accumulators in.
    let session_sum: f64 = reports.iter().map(|r| r.modeled_queueing_s).sum();
    let (agg, metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, 2 * PER_SESSION);
    assert!(
        session_sum > 0.0,
        "1 GHz paced arrivals over µs-scale service must accumulate waiting"
    );
    assert_eq!(
        agg.modeled_queueing_s, session_sum,
        "aggregate modeled_queueing_s must be exactly the per-session sum"
    );
    // The stage metrics carry the same accounting (same values, summed
    // in emission rather than session order — so approximate, not
    // bitwise).
    let stage_sum = metrics.stage_sum_s("modeled_queueing");
    assert!(
        (agg.modeled_queueing_s - stage_sum).abs() <= 1e-12 * stage_sum.max(1.0),
        "stage sum {stage_sum} must agree with the aggregate {}",
        agg.modeled_queueing_s
    );
}
