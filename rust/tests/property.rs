//! Property-based tests over the architecture simulator's invariants
//! (proptest is unavailable offline; cases are generated with the crate's
//! deterministic xorshift PRNG — failures print the seed/case).

use optovit::arch::core::{CoreParams, OpticalCore};
use optovit::arch::mapping::MappingPlan;
use optovit::arch::scheduler::{AttentionSchedule, Resource};
use optovit::arch::workload::Workload;
use optovit::energy::AcceleratorModel;
use optovit::quant::QuantParams;
use optovit::roi::PatchMask;
use optovit::util::rng::Rng;
use optovit::vit::{VitConfig, VitVariant};

const CASES: usize = 120;

/// Every random MatMul mapping covers each (row, k-chunk, col-tile) cell
/// exactly once with no slot collisions — the Fig. 6 invariant.
#[test]
fn prop_mapping_coverage() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..CASES {
        let m = rng.range(1, 64);
        let k = rng.range(1, 512);
        let n = rng.range(1, 512);
        let params = CoreParams { num_cores: rng.range(1, 8), ..CoreParams::default() };
        let plan = MappingPlan::weight_stationary(m, k, n, params);
        assert!(
            plan.validate_coverage().is_none(),
            "case {case}: {m}x{k}x{n} cores={} -> {:?}",
            params.num_cores,
            plan.validate_coverage()
        );
    }
}

/// Mapping makespan never exceeds the single-core chunk count and never
/// beats the perfect-parallel lower bound.
#[test]
fn prop_mapping_makespan_bounds() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..CASES {
        let m = rng.range(1, 48);
        let k = rng.range(1, 300);
        let n = rng.range(1, 300);
        let cores = rng.range(1, 8);
        let params = CoreParams { num_cores: cores, ..CoreParams::default() };
        let plan = MappingPlan::weight_stationary(m, k, n, params);
        let total = plan.chunks.len() as u64;
        let lower = total.div_ceil(cores as u64);
        let makespan = plan.makespan_slots();
        assert!(makespan >= lower && makespan <= total, "{m}x{k}x{n}@{cores}: {lower} <= {makespan} <= {total}");
    }
}

/// Cost-model conservation: cycles * macs_per_cycle == mac_slots, ADC
/// conversions == cycles * arms, and utilization in (0, 1].
#[test]
fn prop_core_cost_conservation() {
    let mut rng = Rng::new(0xFACE);
    let core = OpticalCore::new(CoreParams::default());
    for _ in 0..CASES {
        let m = rng.range(1, 64);
        let k = rng.range(1, 1024);
        let n = rng.range(1, 1024);
        let c = core.matmul_cost(m, k, n);
        assert_eq!(c.mac_slots, c.cycles * 2048);
        assert_eq!(c.adc_conversions, c.cycles * 64);
        assert_eq!(c.vcsel_symbols, c.cycles * 32);
        let u = c.utilization();
        assert!(u > 0.0 && u <= 1.0, "util {u} for {m}x{k}x{n}");
        assert!(c.macs <= c.mac_slots);
    }
}

/// Scheduler causality + per-core compute exclusivity for random shapes.
#[test]
fn prop_schedule_causality_random() {
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..12 {
        let variant = [VitVariant::Tiny, VitVariant::Small][rng.below(2)];
        let cfg = VitConfig::variant(variant, 96, 10);
        let n = rng.range(2, cfg.seq_len() + 1);
        let tune = [40.0, 250.0, 1000.0][rng.below(3)];
        let params = CoreParams { tune_ns: tune, ..CoreParams::default() };
        let decomposed = rng.chance(0.5);
        let s = if decomposed {
            AttentionSchedule::decomposed(&cfg, n, params, 1)
        } else {
            AttentionSchedule::direct(&cfg, n, params, 1)
        };
        let (timing, stats) = s.schedule(5);
        for (i, t) in s.tasks.iter().enumerate() {
            for d in t.compute_after.to_vec() {
                assert!(timing[d].compute_end <= timing[i].compute_start + 1e-9);
            }
            for d in t.tune_after.to_vec() {
                assert!(timing[d].compute_end <= timing[i].tune_start + 1e-9);
            }
        }
        assert!(stats.makespan_ns > 0.0);
        assert!(stats.mean_core_utilization <= 1.0);
        // compute exclusivity per core
        let mut per_core: Vec<Vec<(f64, f64)>> = vec![Vec::new(); 5];
        for (i, t) in s.tasks.iter().enumerate() {
            if let Resource::Core(c) = t.resource {
                per_core[c].push((timing[i].compute_start, timing[i].compute_end));
            }
        }
        for ivs in &mut per_core {
            ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in ivs.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-9);
            }
        }
    }
}

/// Energy monotonicity: more kept patches never costs less energy; more
/// depth/width never costs less.
#[test]
fn prop_energy_monotone_in_patches() {
    let mut rng = Rng::new(0xAB);
    let model = AcceleratorModel::default();
    for _ in 0..40 {
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let a = rng.range(1, 36);
        let b = rng.range(a, 37);
        let ea = model.frame_report("a", &cfg, a, true).energy.total_j();
        let eb = model.frame_report("b", &cfg, b, true).energy.total_j();
        assert!(ea <= eb + 1e-15, "kept {a} -> {ea}, kept {b} -> {eb}");
    }
}

/// Quantization: |fake_quant(x) - x| <= scale/2 and idempotence, for random
/// tensors and bit widths.
#[test]
fn prop_quant_roundtrip() {
    let mut rng = Rng::new(0x51);
    for _ in 0..CASES {
        let bits = rng.range(2, 9) as u32;
        let len = rng.range(1, 256);
        let mut xs = vec![0.0f32; len];
        let scale = rng.uniform(0.01, 100.0) as f32;
        rng.fill_uniform_f32(&mut xs, -scale, scale);
        let p = QuantParams::calibrate(&xs, bits);
        for &x in &xs {
            let q = p.fake_quantize(x);
            assert!((q - x).abs() <= p.max_abs_error() + 1e-5);
            assert_eq!(p.fake_quantize(q), q, "idempotence at {x}");
        }
    }
}

/// PatchMask: gather length == kept * dim; IoU symmetry and bounds.
#[test]
fn prop_mask_gather_and_iou() {
    let mut rng = Rng::new(0x99);
    for _ in 0..CASES {
        let side = rng.range(2, 15);
        let a = PatchMask::random(side, rng.uniform(0.0, 1.0), &mut rng);
        let b = PatchMask::random(side, rng.uniform(0.0, 1.0), &mut rng);
        let iou_ab = a.iou(&b);
        let iou_ba = b.iou(&a);
        assert!((iou_ab - iou_ba).abs() < 1e-12);
        assert!((0.0..=1.0).contains(&iou_ab));
        assert_eq!(a.iou(&a), 1.0);
        let dim = rng.range(1, 8);
        let patches = vec![1.0f32; a.num_patches() * dim];
        assert_eq!(a.gather_patches(&patches, dim).len(), a.kept() * dim);
        assert!((a.skip_ratio() - (1.0 - a.kept() as f64 / a.num_patches() as f64)).abs() < 1e-12);
    }
}

/// Workload MAC counts scale correctly with masking: the unmasked total is
/// an upper bound, and the Embed op scales exactly linearly.
#[test]
fn prop_workload_masking_bounds() {
    let mut rng = Rng::new(0x77);
    for _ in 0..40 {
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let kept = rng.range(1, cfg.num_patches() + 1);
        let w = Workload::vit(&cfg, kept, true);
        let full = Workload::vit(&cfg, cfg.num_patches(), true);
        assert!(w.total_macs() <= full.total_macs());
        let embed = w.matmuls.iter().find(|m| m.site == "embed").unwrap();
        assert_eq!(embed.m, kept, "embed rows must equal kept patches");
        assert_eq!(w.seq_len, kept + 1);
    }
}
