//! Property-based tests over the architecture simulator's invariants —
//! plus the serving coordinator's scheduling invariants (weighted
//! round-robin admission fairness, micro-batcher deadline bounds), which
//! are pure state machines driven on an explicit timeline, so they
//! property-test without threads or wall-clock sleeps. (proptest is
//! unavailable offline; cases are generated with the crate's
//! deterministic xorshift PRNG — failures print the seed/case).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use optovit::arch::core::{CoreParams, OpticalCore};
use optovit::coordinator::batcher::{BatchPolicy, MicroBatcher};
use optovit::coordinator::server::{HealthWeightedWrr, WrrAdmission};
use optovit::arch::mapping::MappingPlan;
use optovit::arch::scheduler::{AttentionSchedule, Resource};
use optovit::arch::workload::Workload;
use optovit::energy::AcceleratorModel;
use optovit::quant::QuantParams;
use optovit::roi::PatchMask;
use optovit::util::rng::Rng;
use optovit::vit::{VitConfig, VitVariant};

const CASES: usize = 120;

/// Weighted round-robin admission fairness ([`WrrAdmission`] — the exact
/// scheduler the server's dispatcher runs): for random weight vectors and
/// deep backlogs, a backlogged session's admitted count after `s` sweeps
/// is **exactly** `s * w_i` (a finite backlog caps at its size), so every
/// session's admitted share is within one round of `w_i / Σw` — a hot
/// tenant cannot starve a small one.
#[test]
fn prop_wrr_admission_share_within_one_round() {
    let mut rng = Rng::new(0x5E55);
    for case in 0..40 {
        let n = rng.range(2, 7);
        let weights: Vec<u32> = (0..n).map(|_| rng.range(1, 6) as u32).collect();
        // Mostly deep backlogs, with some finite ones that exhaust
        // mid-run (an exhausted session must not distort its neighbours).
        let initial: Vec<u64> = (0..n)
            .map(|_| if rng.chance(0.3) { rng.range(0, 40) as u64 } else { 1_000_000 })
            .collect();
        let mut backlog = initial.clone();
        let mut admitted = vec![0u64; n];
        let mut wrr = WrrAdmission::new();
        for sweep in 1..=60u64 {
            wrr.sweep(&weights, |i| {
                if backlog[i] > 0 {
                    backlog[i] -= 1;
                    admitted[i] += 1;
                    true
                } else {
                    false
                }
            });
            for i in 0..n {
                assert_eq!(
                    admitted[i],
                    (sweep * weights[i] as u64).min(initial[i]),
                    "case {case} sweep {sweep} session {i} (w={}): \
                     a backlogged session is granted exactly its weight per sweep",
                    weights[i]
                );
            }
        }
        // Share form of the invariant, for the sessions that never
        // exhausted: |admitted_i − total * w_i / Σw| ≤ w_i (one round).
        let deep: Vec<usize> = (0..n).filter(|&i| initial[i] > 60 * 6).collect();
        let total: u64 = deep.iter().map(|&i| admitted[i]).sum();
        let sum_w: u64 = deep.iter().map(|&i| weights[i] as u64).sum();
        for &i in &deep {
            let fair = total as f64 * weights[i] as f64 / sum_w as f64;
            assert!(
                (admitted[i] as f64 - fair).abs() <= weights[i] as f64 + 1e-9,
                "case {case} session {i}: admitted {} vs fair share {fair} (w={})",
                admitted[i],
                weights[i]
            );
        }
    }
}

/// Health-weighted rotation ([`HealthWeightedWrr`] — the dispatcher's
/// placement tie-break anchor): for random health vectors, including
/// floored (0.0) entries, one full rotation cycle visits **every**
/// worker at least once — health only scales a worker's share within
/// `[1, 4]` credits, it can never starve one — and a pristine worker's
/// share is exactly `credits(h)` per cycle, at most 4x a floored
/// worker's.
#[test]
fn prop_health_weighted_wrr_never_starves_any_worker() {
    // Degenerate shapes first: empty fleets and lone workers pick 0.
    let mut hwrr = HealthWeightedWrr::new();
    assert_eq!(hwrr.next(&[]), 0);
    assert_eq!(hwrr.next(&[0.0]), 0);
    assert_eq!(hwrr.next(&[1.0]), 0);

    let mut rng = Rng::new(0x4EA1);
    for case in 0..60 {
        let n = rng.range(2, 9);
        let healths: Vec<f64> = (0..n)
            .map(|_| {
                if rng.chance(0.25) {
                    0.0 // floored optics — the starvation-prone extreme
                } else {
                    rng.uniform(0.0, 1.0)
                }
            })
            .collect();
        let credits: Vec<u64> =
            healths.iter().map(|&h| HealthWeightedWrr::credits(h) as u64).collect();
        let cycle: u64 = credits.iter().sum();
        const CYCLES: u64 = 10;
        let mut picks = vec![0u64; n];
        let mut hwrr = HealthWeightedWrr::new();
        for _ in 0..cycle * CYCLES {
            let w = hwrr.next(&healths);
            assert!(w < n, "case {case}: pick {w} out of range");
            picks[w] += 1;
        }
        for i in 0..n {
            assert_eq!(
                picks[i],
                credits[i] * CYCLES,
                "case {case} worker {i} (h={:.3}): exactly credits-per-cycle turns",
                healths[i]
            );
            assert!(picks[i] >= CYCLES, "case {case}: worker {i} starved");
        }
        let max = *picks.iter().max().unwrap();
        let min = *picks.iter().min().unwrap();
        assert!(
            max <= 4 * min,
            "case {case}: share spread {max}/{min} exceeds the 4x credit band"
        );
    }
}

/// Flush every matured lane at `now` and forget its items; every flushed
/// group respects `max_batch`.
fn drain_matured(
    b: &mut MicroBatcher<usize>,
    now: Instant,
    max_batch: usize,
    held: &mut BTreeMap<usize, (Instant, Option<Instant>)>,
    case: usize,
) {
    while let Some((_bucket, group)) = b.poll(now) {
        assert!(
            !group.is_empty() && group.len() <= max_batch,
            "case {case}: flushed group of {} exceeds max_batch {max_batch}",
            group.len()
        );
        for id in group {
            held.remove(&id);
        }
    }
}

/// [`MicroBatcher`] under random push/advance sequences on an explicit
/// manual timeline: it never emits a group larger than `max_batch`, and
/// after polling at any time `now` it never holds a frame past
/// `max_wait` — or past the frame's own SLO-derived deadline when that
/// is tighter.
#[test]
fn prop_micro_batcher_bounds_batch_size_and_hold_time() {
    let mut rng = Rng::new(0xBA7C4);
    let buckets = [9usize, 18, 27, 36];
    for case in 0..60 {
        let max_batch = rng.range(1, 6);
        let max_wait = Duration::from_micros(rng.range(1, 5000) as u64);
        let mut b: MicroBatcher<usize> =
            MicroBatcher::new(&buckets, BatchPolicy::batched(max_batch, max_wait));
        let mut now = Instant::now();
        // item id → (pushed_at, optional SLO deadline)
        let mut held: BTreeMap<usize, (Instant, Option<Instant>)> = BTreeMap::new();
        let mut next_id = 0usize;
        for _ in 0..300 {
            if rng.chance(0.6) {
                let bucket = buckets[rng.below(buckets.len())];
                let deadline = rng
                    .chance(0.4)
                    .then(|| now + Duration::from_micros(rng.range(1, 3000) as u64));
                let id = next_id;
                next_id += 1;
                held.insert(id, (now, deadline));
                if let Some((_bkt, group)) = b.push_with_deadline(bucket, id, now, deadline) {
                    assert_eq!(
                        group.len(),
                        max_batch,
                        "case {case}: a size flush is exactly max_batch"
                    );
                    for id in group {
                        held.remove(&id);
                    }
                }
            } else {
                now += Duration::from_micros(rng.range(1, 4000) as u64);
                drain_matured(&mut b, now, max_batch, &mut held, case);
                // The bound: nothing still held is overdue at `now`.
                for (id, (pushed, deadline)) in &held {
                    assert!(
                        now.duration_since(*pushed) < max_wait,
                        "case {case}: item {id} held past max_wait {max_wait:?}"
                    );
                    if let Some(d) = deadline {
                        assert!(
                            now < *d,
                            "case {case}: item {id} held past its SLO-derived deadline"
                        );
                    }
                }
            }
            assert_eq!(b.pending(), held.len(), "case {case}: held-set bookkeeping diverged");
        }
        // End of stream: the forcing drain empties every lane.
        while let Some((_bkt, group)) = b.flush_oldest() {
            assert!(group.len() <= max_batch);
            for id in group {
                held.remove(&id);
            }
        }
        assert!(b.is_empty() && held.is_empty(), "case {case}: frames left behind");
    }
}

/// Every random MatMul mapping covers each (row, k-chunk, col-tile) cell
/// exactly once with no slot collisions — the Fig. 6 invariant.
#[test]
fn prop_mapping_coverage() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..CASES {
        let m = rng.range(1, 64);
        let k = rng.range(1, 512);
        let n = rng.range(1, 512);
        let params = CoreParams { num_cores: rng.range(1, 8), ..CoreParams::default() };
        let plan = MappingPlan::weight_stationary(m, k, n, params);
        assert!(
            plan.validate_coverage().is_none(),
            "case {case}: {m}x{k}x{n} cores={} -> {:?}",
            params.num_cores,
            plan.validate_coverage()
        );
    }
}

/// Mapping makespan never exceeds the single-core chunk count and never
/// beats the perfect-parallel lower bound.
#[test]
fn prop_mapping_makespan_bounds() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..CASES {
        let m = rng.range(1, 48);
        let k = rng.range(1, 300);
        let n = rng.range(1, 300);
        let cores = rng.range(1, 8);
        let params = CoreParams { num_cores: cores, ..CoreParams::default() };
        let plan = MappingPlan::weight_stationary(m, k, n, params);
        let total = plan.chunks.len() as u64;
        let lower = total.div_ceil(cores as u64);
        let makespan = plan.makespan_slots();
        assert!(makespan >= lower && makespan <= total, "{m}x{k}x{n}@{cores}: {lower} <= {makespan} <= {total}");
    }
}

/// Cost-model conservation: cycles * macs_per_cycle == mac_slots, ADC
/// conversions == cycles * arms, and utilization in (0, 1].
#[test]
fn prop_core_cost_conservation() {
    let mut rng = Rng::new(0xFACE);
    let core = OpticalCore::new(CoreParams::default());
    for _ in 0..CASES {
        let m = rng.range(1, 64);
        let k = rng.range(1, 1024);
        let n = rng.range(1, 1024);
        let c = core.matmul_cost(m, k, n);
        assert_eq!(c.mac_slots, c.cycles * 2048);
        assert_eq!(c.adc_conversions, c.cycles * 64);
        assert_eq!(c.vcsel_symbols, c.cycles * 32);
        let u = c.utilization();
        assert!(u > 0.0 && u <= 1.0, "util {u} for {m}x{k}x{n}");
        assert!(c.macs <= c.mac_slots);
    }
}

/// Scheduler causality + per-core compute exclusivity for random shapes.
#[test]
fn prop_schedule_causality_random() {
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..12 {
        let variant = [VitVariant::Tiny, VitVariant::Small][rng.below(2)];
        let cfg = VitConfig::variant(variant, 96, 10);
        let n = rng.range(2, cfg.seq_len() + 1);
        let tune = [40.0, 250.0, 1000.0][rng.below(3)];
        let params = CoreParams { tune_ns: tune, ..CoreParams::default() };
        let decomposed = rng.chance(0.5);
        let s = if decomposed {
            AttentionSchedule::decomposed(&cfg, n, params, 1)
        } else {
            AttentionSchedule::direct(&cfg, n, params, 1)
        };
        let (timing, stats) = s.schedule(5);
        for (i, t) in s.tasks.iter().enumerate() {
            for d in t.compute_after.to_vec() {
                assert!(timing[d].compute_end <= timing[i].compute_start + 1e-9);
            }
            for d in t.tune_after.to_vec() {
                assert!(timing[d].compute_end <= timing[i].tune_start + 1e-9);
            }
        }
        assert!(stats.makespan_ns > 0.0);
        assert!(stats.mean_core_utilization <= 1.0);
        // compute exclusivity per core
        let mut per_core: Vec<Vec<(f64, f64)>> = vec![Vec::new(); 5];
        for (i, t) in s.tasks.iter().enumerate() {
            if let Resource::Core(c) = t.resource {
                per_core[c].push((timing[i].compute_start, timing[i].compute_end));
            }
        }
        for ivs in &mut per_core {
            ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in ivs.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-9);
            }
        }
    }
}

/// Energy monotonicity: more kept patches never costs less energy; more
/// depth/width never costs less.
#[test]
fn prop_energy_monotone_in_patches() {
    let mut rng = Rng::new(0xAB);
    let model = AcceleratorModel::default();
    for _ in 0..40 {
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let a = rng.range(1, 36);
        let b = rng.range(a, 37);
        let ea = model.frame_report("a", &cfg, a, true).energy.total_j();
        let eb = model.frame_report("b", &cfg, b, true).energy.total_j();
        assert!(ea <= eb + 1e-15, "kept {a} -> {ea}, kept {b} -> {eb}");
    }
}

/// Quantization: |fake_quant(x) - x| <= scale/2 and idempotence, for random
/// tensors and bit widths.
#[test]
fn prop_quant_roundtrip() {
    let mut rng = Rng::new(0x51);
    for _ in 0..CASES {
        let bits = rng.range(2, 9) as u32;
        let len = rng.range(1, 256);
        let mut xs = vec![0.0f32; len];
        let scale = rng.uniform(0.01, 100.0) as f32;
        rng.fill_uniform_f32(&mut xs, -scale, scale);
        let p = QuantParams::calibrate(&xs, bits);
        for &x in &xs {
            let q = p.fake_quantize(x);
            assert!((q - x).abs() <= p.max_abs_error() + 1e-5);
            assert_eq!(p.fake_quantize(q), q, "idempotence at {x}");
        }
    }
}

/// PatchMask: gather length == kept * dim; IoU symmetry and bounds.
#[test]
fn prop_mask_gather_and_iou() {
    let mut rng = Rng::new(0x99);
    for _ in 0..CASES {
        let side = rng.range(2, 15);
        let a = PatchMask::random(side, rng.uniform(0.0, 1.0), &mut rng);
        let b = PatchMask::random(side, rng.uniform(0.0, 1.0), &mut rng);
        let iou_ab = a.iou(&b);
        let iou_ba = b.iou(&a);
        assert!((iou_ab - iou_ba).abs() < 1e-12);
        assert!((0.0..=1.0).contains(&iou_ab));
        assert_eq!(a.iou(&a), 1.0);
        let dim = rng.range(1, 8);
        let patches = vec![1.0f32; a.num_patches() * dim];
        assert_eq!(a.gather_patches(&patches, dim).len(), a.kept() * dim);
        assert!((a.skip_ratio() - (1.0 - a.kept() as f64 / a.num_patches() as f64)).abs() < 1e-12);
    }
}

/// Workload MAC counts scale correctly with masking: the unmasked total is
/// an upper bound, and the Embed op scales exactly linearly.
#[test]
fn prop_workload_masking_bounds() {
    let mut rng = Rng::new(0x77);
    for _ in 0..40 {
        let cfg = VitConfig::variant(VitVariant::Tiny, 96, 10);
        let kept = rng.range(1, cfg.num_patches() + 1);
        let w = Workload::vit(&cfg, kept, true);
        let full = Workload::vit(&cfg, cfg.num_patches(), true);
        assert!(w.total_macs() <= full.total_macs());
        let embed = w.matmuls.iter().find(|m| m.site == "embed").unwrap();
        assert_eq!(embed.m, kept, "embed rows must equal kept patches");
        assert_eq!(w.seq_len, kept + 1);
    }
}
