//! Integration tests over the real PJRT runtime + compiled artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! message) when the artifact directory is missing so `cargo test` stays
//! green on a fresh checkout. All PJRT work happens inside a single test
//! body: `PjRtClient` is not `Send`, and artifact compilation (~30 s per
//! backbone bucket) is the dominant cost, so one sequential flow exercises
//! the full pipeline.
//!
//! The whole file is gated on the `pjrt` cargo feature (the backend it
//! exercises is compiled out by default); without it the test target
//! compiles empty.
#![cfg(feature = "pjrt")]

use optovit::coordinator::pipeline::{serve, Pipeline, PipelineConfig, ServeOptions};
use optovit::runtime::{PjrtBackend, Tensor};
use optovit::sensor::VideoSource;

fn artifact_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("mgnet_96.hlo.txt").exists() {
            return Some(dir.to_string());
        }
    }
    None
}

#[test]
fn runtime_and_pipeline_end_to_end() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };

    // --- runtime level: raw artifact execution ---
    let mut rt = PjrtBackend::new(&dir).expect("runtime");
    let names = rt.available();
    assert!(names.contains(&"mgnet_96".to_string()), "{names:?}");
    assert!(names.contains(&"vit_tiny_96_n36".to_string()), "{names:?}");

    let scores = rt
        .execute1("mgnet_96", &[Tensor::new(vec![0.25; 36 * 768], vec![36, 768])])
        .expect("mgnet exec");
    assert_eq!(scores.len(), 36);
    assert!(scores.iter().all(|s| s.is_finite()));

    // Determinism: same input, same output.
    let scores2 = rt
        .execute1("mgnet_96", &[Tensor::new(vec![0.25; 36 * 768], vec![36, 768])])
        .expect("mgnet exec 2");
    assert_eq!(scores, scores2);

    // --- pipeline level: masked serving over a live sensor ---
    let cfg = PipelineConfig {
        buckets: vec![9, 36], // subset: keeps compile time bounded
        ..PipelineConfig::tiny_96()
    };
    let mut pipeline =
        Pipeline::with_backend(cfg, PjrtBackend::new(&dir).expect("backend")).expect("pipeline");
    let opts = ServeOptions { sensor_seed: 7, ..ServeOptions::frames(12) };
    let report = serve(&mut pipeline, &opts).expect("serve").finish().expect("drain stream");
    assert_eq!(report.frames, 12);
    assert_eq!(report.backend, "pjrt");
    assert!(report.mean_latency_s > 0.0);
    assert!(report.mean_kept_patches >= 1.0);
    assert!(report.mean_energy_j > 0.0);
    // With a trained MGNet the mask should beat random (IoU > 0.2); with
    // --no-train artifacts this is weaker, so only sanity-bound it.
    assert!((0.0..=1.0).contains(&report.mean_mask_iou));
    // Masked serving must model less energy than unmasked.
    let mut cfg_full = PipelineConfig { buckets: vec![9, 36], ..PipelineConfig::tiny_96() };
    cfg_full.use_mask = false;
    let mut full = Pipeline::with_backend(cfg_full, PjrtBackend::new(&dir).expect("backend"))
        .expect("pipeline full");
    let f = full.next_frame_report();
    // Batched execution over the compiled artifacts matches per-frame
    // dispatch bitwise (same executable, same literals).
    let mut sensor_b = VideoSource::new(96, 2, 123);
    let frames: Vec<_> = (0..3).map(|_| sensor_b.next_frame()).collect();
    let batched = pipeline.process_batch(&frames).expect("pjrt process_batch");
    for (frame, r) in frames.iter().zip(&batched) {
        let direct = pipeline.process_frame(frame).expect("pjrt frame");
        assert_eq!(r.logits, direct.logits, "batched PJRT logits must match per-frame");
        assert_eq!(r.bucket, direct.bucket);
    }
    assert!(report.mean_energy_j < f, "masked {} !< full {}", report.mean_energy_j, f);

    // --- per-frame invariants ---
    let mut sensor = VideoSource::new(96, 2, 99);
    let frame = sensor.next_frame();
    let r = pipeline.process_frame(&frame).expect("frame");
    assert_eq!(r.logits.len(), 10);
    assert!(r.bucket == 9 || r.bucket == 36);
    assert!(r.mask.kept() <= 36);
}

// Helper on Pipeline for the energy comparison above.
trait FullEnergy {
    fn next_frame_report(&mut self) -> f64;
}

impl FullEnergy for Pipeline<PjrtBackend> {
    fn next_frame_report(&mut self) -> f64 {
        let mut sensor = VideoSource::new(96, 2, 99);
        let frame = sensor.next_frame();
        self.process_frame(&frame).expect("full frame").modeled_energy_j
    }
}
