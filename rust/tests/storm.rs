//! Deterministic fleet-elasticity gate — the autoscaler's closed loop
//! proven under a step-controlled [`ManualClock`] with **exact**
//! expectations (counts asserted with `==`, event times to 1e-9):
//!
//! 1. a 10-frame burst that a fixed 1-worker pool **provably** misses
//!    (exactly 6 SLO misses: frame `k` emits at `k-1` seconds against a
//!    3.5 s SLO) is held at **zero** misses by the autoscaler, which
//!    scales 1 → 4 workers while the burst queues and back down to 1
//!    once it drains;
//! 2. the scale-event log is exact — actions `[Up, Up, Up, Down, Down,
//!    Down]` at `t = [0, 1, 2, 4, 6, 8]` s with pool sizes
//!    `[2, 3, 4, 3, 2, 1]` — and consecutive same-direction events
//!    respect their cooldowns; a second tick at the same instant adds
//!    nothing;
//! 3. admission shedding at the capacity cap rejects only the
//!    lowest-weight session, counts the distinct `dropped_shed` (never
//!    `dropped` / `dropped_quota`), and the aggregate equals the exact
//!    per-session sum; shedding lifts once calm;
//! 4. a lone serving worker is never drained ([`ScaleError::AtFloor`]).
//!
//! Synchronization discipline: time moves only on `advance`; worker
//! progress is gated by a counting semaphore (one frame per released
//! permit), and every wait is a bounded real-time spin on server
//! counters — no `thread::sleep`-based timing anywhere.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;
use optovit::coordinator::autoscale::{AutoScaler, ScaleAction, ScalePolicy};
use optovit::coordinator::batcher::{BatchPolicy, BucketRouter, PushOutcome};
use optovit::coordinator::clock::{Clock, ManualClock};
use optovit::coordinator::engine::{EngineConfig, FrameWorker};
use optovit::coordinator::loadgen::{run_scenario, Scenario, StormConfig};
use optovit::coordinator::pipeline::FrameResult;
use optovit::coordinator::server::{ScaleError, Server, SessionOptions};
use optovit::coordinator::StageMetrics;
use optovit::sensor::{Frame, VideoSource};

const PATCH_PX: usize = 16;

/// Counting semaphore shared by every worker: one frame completes per
/// released permit, so the test decides exactly how many frames emit at
/// each manual-clock instant (which worker consumes a permit is
/// irrelevant — latency depends only on release timing).
#[derive(Clone)]
struct Permits(Arc<(Mutex<u64>, Condvar)>);

impl Permits {
    fn new() -> Self {
        Permits(Arc::new((Mutex::new(0), Condvar::new())))
    }

    fn release(&self, n: u64) {
        let (count, wake) = &*self.0;
        *count.lock().unwrap() += n;
        wake.notify_all();
    }

    fn acquire(&self) {
        let (count, wake) = &*self.0;
        let mut held = count.lock().unwrap();
        while *held == 0 {
            held = wake.wait(held).unwrap();
        }
        *held -= 1;
    }
}

/// Deterministic worker gated on [`Permits`]: echoes the ground-truth
/// mask (the qos-gate idiom) after acquiring one permit per frame.
struct GatedEchoWorker {
    permits: Permits,
    router: BucketRouter,
    metrics: StageMetrics,
}

impl GatedEchoWorker {
    fn new(permits: Permits) -> Self {
        GatedEchoWorker {
            permits,
            router: BucketRouter::even(36, 4),
            metrics: StageMetrics::new(),
        }
    }
}

impl FrameWorker for GatedEchoWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        self.permits.acquire();
        let mask = frame.gt_mask(PATCH_PX);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        self.metrics.record_stage("total", 1e-4);
        self.metrics.record_frame(1e-5, kept);
        self.metrics.record_batch_size(1);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        Ok(FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: 1e-4,
            modeled_queueing_s: 0.0,
            batch_size: 1,
            tier: optovit::quant::PrecisionTier::Int8,
            fp32_agreement: None,
        })
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }
}

/// An elastic 1-worker server on a manual clock: batch size 1 (one
/// permit per frame), worker channels deep enough that every burst
/// frame places immediately (the queue-depth gauge sees the whole
/// backlog).
fn storm_server(max_workers: usize, permits: &Permits) -> (Server, ManualClock) {
    let (clock, manual) = Clock::manual();
    let mut cfg = EngineConfig::new(1, PATCH_PX, 96);
    cfg.clock = clock;
    cfg.batch = BatchPolicy::batched(1, Duration::from_secs(3600));
    cfg.queue_depth = 16;
    cfg.max_workers = max_workers;
    cfg.warmup_timeout_s = 24.0 * 3600.0;
    cfg.stall_timeout_s = 24.0 * 3600.0;
    let permits = permits.clone();
    let server =
        Server::start(move |_wid| Ok(GatedEchoWorker::new(permits.clone())), cfg).expect("server");
    server.wait_ready(Duration::from_secs(3600)).expect("workers warm");
    (server, manual)
}

/// Identical frames with distinct indices (content never affects
/// grouping or routing determinism).
fn frames(n: u64) -> Vec<Frame> {
    let template = VideoSource::new(96, 2, 42).next_frame();
    (0..n)
        .map(|i| {
            let mut f = template.clone();
            f.index = i;
            f
        })
        .collect()
}

/// Bounded real-time spin on a server-observable condition; manual time
/// never moves here, so the 30 s wall bailout only trips on a hang.
fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = std::time::Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::yield_now();
    }
}

fn emitted(server: &Server) -> u64 {
    server.stats().expect("stats").aggregate.frames
}

fn queue_depth(server: &Server) -> u64 {
    server
        .stats()
        .expect("stats")
        .worker_health
        .iter()
        .map(|w| w.queue_depth)
        .sum()
}

/// The control arm: a fixed 1-worker pool served the same 10-frame
/// burst at one frame per second — frame `k` emits at `k-1` s, so a
/// 3.5 s SLO misses on exactly the last six frames. This is the number
/// the autoscaled arm must beat to zero.
#[test]
fn fixed_pool_provably_misses_the_burst() {
    let permits = Permits::new();
    let (server, manual) = storm_server(0, &permits);
    let mut session = server
        .session(
            SessionOptions::named("slo-cam")
                .with_queue_depth(16)
                .with_window(16)
                .with_slo(Duration::from_millis(3500)),
        )
        .expect("session");

    for f in frames(10) {
        session.submit(f).expect("submit");
    }
    for k in 1..=10u64 {
        permits.release(1);
        wait_for("burst frame emission", || emitted(&server) == k);
        manual.advance(Duration::from_secs(1));
    }

    session.close();
    let report = session.finish().expect("drain");
    assert_eq!(report.frames, 10);
    assert_eq!(
        report.slo_miss, 6,
        "latencies 0..=9 s against a 3.5 s SLO: frames 5..=10 miss, exactly six"
    );
    assert_eq!(report.dropped, 0);
    assert_eq!(report.dropped_quota, 0);
    assert_eq!(report.dropped_shed, 0);
    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.slo_miss, 6);
}

/// The autoscaled arm: the same burst, but an [`AutoScaler`] ticked once
/// per simulated second grows the pool 1 → 4 while the backlog queues
/// (draining it in waves of 1, 2, 3, 4 — worst latency 3 s, zero
/// misses) and retires workers back to 1 once calm, with the exact
/// event log and cooldown spacing asserted.
#[test]
fn autoscaler_holds_the_slo_through_the_burst_and_scales_back_down() {
    let permits = Permits::new();
    let (server, manual) = storm_server(4, &permits);
    let policy = ScalePolicy {
        min_workers: 1,
        max_workers: 4,
        up_queue_depth: 1.25,
        up_miss_rate: 0.05,
        down_queue_depth: 0.25,
        up_cooldown: Duration::from_secs(1),
        down_cooldown: Duration::from_secs(2),
        shed_after: 1000,
    };
    let clock = server.clock();
    let mut scaler = AutoScaler::new(policy, clock);
    let mut session = server
        .session(
            SessionOptions::named("slo-cam")
                .with_queue_depth(16)
                .with_window(16)
                .with_slo(Duration::from_millis(3500)),
        )
        .expect("session");

    for f in frames(10) {
        session.submit(f).expect("submit");
    }
    wait_for("burst placement", || queue_depth(&server) == 10);

    // Drain waves sized to the live pool: 1 @ t0, 2 @ t1, 3 @ t2,
    // 4 @ t3 — the scaler grows the pool one worker per tick while the
    // backlog holds the queue-depth signal above the up band.
    let mut left = 10u64;
    let mut expect_live = 1usize;
    for tick in 0..4u64 {
        let wave = (tick + 1).min(left);
        permits.release(wave);
        left -= wave;
        wait_for("wave emission", || emitted(&server) == 10 - left);
        wait_for("wave completion drains the gauge", || queue_depth(&server) == left);
        let action = scaler.tick(&server).expect("tick");
        if tick < 3 {
            assert_eq!(action, Some(ScaleAction::Up), "tick {tick} must scale up");
            expect_live += 1;
            wait_for("spawned worker goes live", || {
                server.stats().expect("stats").live_workers == expect_live
            });
        } else {
            assert_eq!(action, None, "tick 3: calm, but still inside down_cooldown — hold");
            // Same-instant double tick: nothing changes, nothing fires.
            assert_eq!(scaler.tick(&server).expect("re-tick"), None);
        }
        manual.advance(Duration::from_secs(1));
    }
    assert_eq!(left, 0, "waves 1+2+3+4 drain the whole burst");

    // Calm ticks: scale-down every down_cooldown (2 s), never below 1.
    for tick in 4..10u64 {
        let action = scaler.tick(&server).expect("tick");
        if tick % 2 == 0 && expect_live > 1 {
            assert_eq!(action, Some(ScaleAction::Down), "tick {tick} must scale down");
            expect_live -= 1;
            wait_for("retired worker leaves the pool", || {
                server.stats().expect("stats").live_workers == expect_live
            });
        } else {
            assert_eq!(action, None, "tick {tick} must hold (cooldown or at floor)");
        }
        manual.advance(Duration::from_secs(1));
    }
    assert_eq!(expect_live, 1);

    // The lone survivor is never drained — by the scaler or directly.
    assert_eq!(scaler.tick(&server).expect("tick"), None);
    assert!(matches!(server.scale_down(), Err(ScaleError::AtFloor)));

    // Exact event log: actions, pool sizes, timestamps, cooldown gaps.
    let events = server.scale_events();
    let actions: Vec<&ScaleAction> = events.iter().map(|e| &e.action).collect();
    assert_eq!(
        actions,
        vec![
            &ScaleAction::Up,
            &ScaleAction::Up,
            &ScaleAction::Up,
            &ScaleAction::Down,
            &ScaleAction::Down,
            &ScaleAction::Down,
        ]
    );
    let workers: Vec<usize> = events.iter().map(|e| e.workers).collect();
    assert_eq!(workers, vec![2, 3, 4, 3, 2, 1]);
    let expected_at = [0.0, 1.0, 2.0, 4.0, 6.0, 8.0];
    for (e, want) in events.iter().zip(expected_at) {
        assert!(
            (e.at_s - want).abs() < 1e-9,
            "event at {} s, expected {} s",
            e.at_s,
            want
        );
    }
    for gap in events[..3].windows(2) {
        assert!(gap[1].at_s - gap[0].at_s >= 1.0 - 1e-9, "up_cooldown respected");
    }
    for gap in events[3..].windows(2) {
        assert!(gap[1].at_s - gap[0].at_s >= 2.0 - 1e-9, "down_cooldown respected");
    }

    session.close();
    let report = session.finish().expect("drain");
    assert_eq!(report.frames, 10);
    assert_eq!(
        report.slo_miss, 0,
        "the elastic pool drains the burst within 3 s — zero misses against 3.5 s"
    );
    assert_eq!(report.dropped, 0);
    assert_eq!(report.dropped_quota, 0);
    assert_eq!(report.dropped_shed, 0);

    // Retired workers keep their final rows: totals stay monotone.
    let stats = server.stats().expect("stats");
    assert_eq!(stats.live_workers, 1);
    let retired = stats.worker_health.len() - stats.live_workers;
    assert_eq!(retired, 3, "three retired workers keep their final rows");

    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.slo_miss, 0);
    assert_eq!(agg.frames, 10);
    assert_eq!(agg.workers, 4, "every worker that ever served is accounted");
}

/// Shedding at the capacity cap: overloaded ticks with nowhere to grow
/// arm admission shedding against the lowest weight class only. Shed
/// rejections land in the distinct `dropped_shed` — never `dropped` or
/// `dropped_quota` — the aggregate equals the per-session sum exactly,
/// and shedding lifts once the backlog drains.
#[test]
fn capped_pool_sheds_lowest_weight_first_and_counts_dropped_shed() {
    let permits = Permits::new();
    let (server, _manual) = storm_server(1, &permits);
    let policy = ScalePolicy {
        min_workers: 1,
        max_workers: 1,
        up_queue_depth: 1.0,
        shed_after: 2,
        ..ScalePolicy::default()
    };
    let mut scaler = AutoScaler::new(policy, server.clock());
    let mut lo = server
        .session(SessionOptions::named("lo").with_weight(1).with_queue_depth(16).with_window(16))
        .expect("lo");
    let mut hi = server
        .session(SessionOptions::named("hi").with_weight(2).with_queue_depth(16).with_window(16))
        .expect("hi");

    // Overload from the high-weight tenant: four queued frames on a
    // 1-worker pool that cannot grow.
    let mut hi_frames = frames(8).into_iter();
    for _ in 0..4 {
        assert_eq!(hi.try_submit(hi_frames.next().unwrap()), PushOutcome::Queued);
    }
    wait_for("backlog placement", || queue_depth(&server) == 4);

    assert_eq!(scaler.tick(&server).expect("tick 1"), None, "one overloaded tick is not enough");
    assert_eq!(
        scaler.tick(&server).expect("tick 2"),
        Some(ScaleAction::ShedOn { below_weight: 2 }),
        "two consecutive capped ticks arm shedding below the second weight class"
    );

    // The low-weight tenant is turned away — distinctly.
    let mut lo_frames = frames(4).into_iter();
    for _ in 0..3 {
        assert_eq!(lo.try_submit(lo_frames.next().unwrap()), PushOutcome::Shed);
    }
    {
        let report = lo.report();
        assert_eq!(report.dropped_shed, 3, "every shed rejection counts dropped_shed");
        assert_eq!(report.dropped, 0, "shedding is not backpressure");
        assert_eq!(report.dropped_quota, 0, "shedding is not a quota");
    }
    // The high-weight tenant still admits.
    assert_eq!(hi.try_submit(hi_frames.next().unwrap()), PushOutcome::Queued);

    // Drain the backlog; a calm tick lifts shedding before anything else.
    permits.release(5);
    wait_for("backlog drains", || emitted(&server) == 5 && queue_depth(&server) == 0);
    assert_eq!(scaler.tick(&server).expect("tick 3"), Some(ScaleAction::ShedOff));
    assert_eq!(lo.try_submit(lo_frames.next().unwrap()), PushOutcome::Queued, "re-admitted");
    permits.release(1);
    wait_for("lo frame emits", || emitted(&server) == 6);

    // Never a scale event on this pool — only the shed pair — and the
    // lone worker is never drained.
    let actions: Vec<ScaleAction> =
        server.scale_events().into_iter().map(|e| e.action).collect();
    assert_eq!(actions, vec![ScaleAction::ShedOn { below_weight: 2 }, ScaleAction::ShedOff]);
    assert!(matches!(server.scale_down(), Err(ScaleError::AtFloor)));

    lo.close();
    hi.close();
    let lo_report = lo.finish().expect("lo drain");
    let hi_report = hi.finish().expect("hi drain");
    assert_eq!(lo_report.frames, 1);
    assert_eq!(lo_report.dropped_shed, 3);
    assert_eq!(hi_report.frames, 5);
    assert_eq!(hi_report.dropped_shed, 0);
    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(
        agg.dropped_shed,
        lo_report.dropped_shed + hi_report.dropped_shed,
        "aggregate dropped_shed is exactly the per-session sum"
    );
    assert_eq!(agg.dropped, 0);
    assert_eq!(agg.dropped_quota, 0);
}

/// End-to-end storm harness smoke: a 10-session 10x burst scenario under
/// the loadgen driver completes every arrival (deep queues, no
/// shedding), scales up during the burst, and samples the offered-load
/// plateau — the `serve_storm` bench path exercised as a gate.
#[test]
fn loadgen_burst_scenario_scales_up_and_completes_every_arrival() {
    let mut cfg = EngineConfig::new(2, PATCH_PX, 96);
    cfg.batch = BatchPolicy::batched(8, Duration::from_millis(1));
    cfg.queue_depth = 16;
    cfg.max_workers = 6;
    cfg.warmup_timeout_s = 24.0 * 3600.0;
    cfg.stall_timeout_s = 24.0 * 3600.0;
    let storm = StormConfig {
        tick: Duration::from_secs(1),
        sample_every: 2,
        service: Duration::from_millis(500),
        slo: Some(Duration::from_secs(2)),
        autoscale: Some(ScalePolicy {
            min_workers: 2,
            max_workers: 6,
            up_cooldown: Duration::from_secs(1),
            shed_after: 1000,
            ..ScalePolicy::default()
        }),
    };
    // 4 fps base, 10x for 5 s: 60 base + 200 burst arrivals.
    let scenario = Scenario::burst("burst10x", 10, 20.0, 4.0, 10.0, 5.0, 10.0);
    assert_eq!(scenario.arrivals().len(), 260);

    let outcome = run_scenario(cfg, &storm, &scenario).expect("storm sweep");
    assert_eq!(outcome.frames, 260, "deep queues + elastic pool: every arrival completes");
    assert_eq!(outcome.dropped, 0);
    assert_eq!(outcome.dropped_quota, 0);
    assert_eq!(outcome.dropped_shed, 0, "shed_after 1000 keeps shedding out of this sweep");
    assert!(
        outcome.scale_events.iter().any(|e| e.action == ScaleAction::Up),
        "the 10x burst must trigger at least one scale-up"
    );
    assert!(!outcome.samples.is_empty());
    let peak = outcome.samples.iter().map(|s| s.offered_fps).fold(0.0, f64::max);
    assert!((peak - 40.0).abs() < 1e-9, "the sampled offered curve shows the 10x plateau");
    assert!(
        outcome.live_workers >= 2 && outcome.live_workers <= 6,
        "the pool ends within its policy bounds"
    );
}
