//! Deterministic QoS gate — the serving stack's time-dependent semantics
//! proven under a step-controlled [`ManualClock`], with **exact** (not
//! threshold-fuzzy) expectations and **zero** `thread::sleep`-based
//! synchronization (grep this file: there is no `sleep` anywhere; all
//! blocking is channel receives and clock-event waits, and time moves
//! only when a test calls `advance`):
//!
//! 1. deadline-aware flush: an SLO session's micro-batch lane flushes
//!    **early** — exactly when the manual clock reaches the frame's
//!    `accepted_at + slo` deadline, overriding a `BatchPolicy::max_wait`
//!    of an hour — and records **no** `slo_miss`, while a no-SLO
//!    neighbour on the same server still amortizes full batches;
//! 2. a flush past the deadline records exactly one `slo_miss` per late
//!    frame, and the server-wide aggregate `slo_miss` equals the
//!    per-session sum;
//! 3. admission quotas: quota-exceeded `try_submit`s return
//!    [`PushOutcome::Quota`] and count the distinct `dropped_quota` —
//!    never `dropped` — for both the in-flight cap and the token-bucket
//!    rate (whose refill is driven purely by manual-clock advances);
//! 4. earliest-deadline-first admission: with two SLO sessions queued
//!    while the worker warms, the dispatcher's EDF pre-pass admits the
//!    imminent deadline first, overriding plain admission order.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use optovit::coordinator::batcher::{BatchPolicy, BucketRouter, PushOutcome};
use optovit::coordinator::clock::{Clock, ManualClock};
use optovit::coordinator::engine::{EngineConfig, FrameWorker};
use optovit::coordinator::pipeline::FrameResult;
use optovit::coordinator::server::{Quota, Server, SessionOptions};
use optovit::coordinator::StageMetrics;
use optovit::sensor::{Frame, VideoSource};

const PATCH_PX: usize = 16;

/// Deterministic batch-aware worker: routes from the ground-truth mask
/// and stamps each result with the size of the group it rode in, so
/// per-session `mean_batch` shows exactly how the server grouped frames.
struct BatchEchoWorker {
    router: BucketRouter,
    metrics: StageMetrics,
}

impl BatchEchoWorker {
    fn new() -> Self {
        BatchEchoWorker { router: BucketRouter::even(36, 4), metrics: StageMetrics::new() }
    }

    fn result(&mut self, frame: &Frame, batch_size: usize) -> FrameResult {
        let mask = frame.gt_mask(PATCH_PX);
        let kept = mask.kept().max(1);
        let bucket = self.router.route(kept);
        self.metrics.record_stage("total", 1e-4);
        self.metrics.record_frame(1e-5, kept);
        self.metrics.record_batch_size(batch_size);
        let mut logits = vec![0.0f32; 10];
        logits[frame.label % 10] = 1.0;
        FrameResult {
            frame_index: frame.index,
            logits,
            mask,
            bucket,
            modeled_energy_j: 1e-5,
            latency_s: 1e-4,
            modeled_queueing_s: 0.0,
            batch_size,
            tier: optovit::quant::PrecisionTier::Int8,
            fp32_agreement: None,
        }
    }
}

impl FrameWorker for BatchEchoWorker {
    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        Ok(self.result(frame, 1))
    }

    fn process_batch(&mut self, frames: &[Frame]) -> Result<Vec<FrameResult>> {
        let n = frames.len().max(1);
        Ok(frames.iter().map(|f| self.result(f, n)).collect())
    }

    fn take_metrics(&mut self) -> StageMetrics {
        std::mem::take(&mut self.metrics)
    }
}

/// One worker on a manual clock with a micro-batch policy whose
/// `max_wait` is an hour: without deadline-aware flushes, a partial lane
/// would only ever flush by filling to `max_batch`.
fn manual_server(max_batch: usize) -> (Server, ManualClock) {
    let (clock, manual) = Clock::manual();
    let mut cfg = EngineConfig::new(1, PATCH_PX, 96);
    cfg.clock = clock;
    cfg.batch = BatchPolicy::batched(max_batch, Duration::from_secs(3600));
    // Manual time never advances past these on its own; generous bounds
    // keep test-driven advances from tripping them.
    cfg.warmup_timeout_s = 24.0 * 3600.0;
    cfg.stall_timeout_s = 24.0 * 3600.0;
    let server = Server::start(|_wid| Ok(BatchEchoWorker::new()), cfg).expect("server");
    // Blocks on the readiness notification — the manual deadline below is
    // unreachable without an advance, so this cannot time out spuriously.
    server.wait_ready(Duration::from_secs(3600)).expect("workers warm");
    (server, manual)
}

/// Identical frame content with distinct indices: every submission routes
/// to the same bucket, so grouping depends only on the server's batching
/// policy, never on scene content.
fn frames(n: u64) -> Vec<Frame> {
    let template = VideoSource::new(96, 2, 42).next_frame();
    (0..n)
        .map(|i| {
            let mut f = template.clone();
            f.index = i;
            f
        })
        .collect()
}

/// Gate 1: the SLO session's lane flushes exactly at its deadline (hours
/// before `max_wait`) with no `slo_miss`, while the no-SLO neighbour
/// amortizes a full batch of 4 on the same server.
#[test]
fn slo_lane_flushes_early_and_records_no_miss() {
    const SLO: Duration = Duration::from_millis(10);
    let (server, manual) = manual_server(4);
    let mut bulk =
        server.session(SessionOptions::named("bulk").with_queue_depth(8)).expect("bulk");
    let mut slo = server
        .session(SessionOptions::named("slo").with_queue_depth(8).with_slo(SLO))
        .expect("slo");

    // The bulk tenant fills a whole group: flushes by *count*, no time
    // needed — batching still works with the clock frozen.
    for f in frames(4) {
        bulk.submit(f).expect("bulk submit");
    }
    for _ in 0..4 {
        let r = (&mut bulk).next().expect("bulk result").expect("bulk ok");
        assert_eq!(r.batch_size, 4, "the bulk group must amortize the full max_batch");
    }

    // The SLO tenant parks one frame in a lane. With max_wait = 1 h and
    // max_batch = 4, nothing can flush it while the clock stands still…
    slo.submit(frames(1).remove(0)).expect("slo submit");
    assert_eq!(slo.report().frames, 0, "no flush may happen before the SLO deadline");

    // …and one atomic advance to exactly the deadline flushes it alone.
    manual.advance(SLO);
    let r = (&mut slo).next().expect("slo result").expect("slo ok");
    assert_eq!(r.batch_size, 1, "the deadline-aware flush must not wait for max_batch");

    slo.close();
    bulk.close();
    let slo_report = slo.finish().expect("slo drain");
    let bulk_report = bulk.finish().expect("bulk drain");
    assert_eq!(slo_report.frames, 1);
    assert_eq!(slo_report.slo_miss, 0, "emitted exactly at the deadline — not a miss");
    assert_eq!(bulk_report.frames, 4);
    assert_eq!(bulk_report.slo_miss, 0, "no SLO declared, no misses");
    assert!((bulk_report.mean_batch - 4.0).abs() < 1e-12, "bulk mean_batch must be exactly 4");
    assert_eq!(slo_report.dropped, 0);
    assert_eq!(slo_report.dropped_quota, 0);

    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.frames, 5);
    assert_eq!(agg.slo_miss, 0);
}

/// Gate 2: a flush past the deadline records exactly one miss per late
/// frame, p99 reflects the late emission, and the aggregate `slo_miss`
/// equals the per-session sum — live (`stats()`) and terminal.
#[test]
fn late_emissions_count_slo_misses_and_aggregate_equals_session_sum() {
    let (server, manual) = manual_server(4);
    let mut tight = server
        .session(SessionOptions::named("tight").with_slo(Duration::from_millis(10)))
        .expect("tight");
    let mut loose = server
        .session(SessionOptions::named("loose").with_slo(Duration::from_millis(20)))
        .expect("loose");

    tight.submit(frames(1).remove(0)).expect("tight submit");
    loose.submit(frames(1).remove(0)).expect("loose submit");
    // One atomic jump well past both deadlines: both frames emit at
    // +50 ms on the manual timeline — 50 > 10 and 50 > 20, so exactly one
    // miss each, regardless of how the worker grouped them.
    manual.advance(Duration::from_millis(50));

    tight.close();
    loose.close();
    let tight_report = tight.finish().expect("tight drain");
    let loose_report = loose.finish().expect("loose drain");
    assert_eq!(tight_report.frames, 1);
    assert_eq!(loose_report.frames, 1);
    assert_eq!(tight_report.slo_miss, 1, "a 50 ms emission misses a 10 ms SLO exactly once");
    assert_eq!(loose_report.slo_miss, 1, "a 50 ms emission misses a 20 ms SLO exactly once");
    assert!(
        tight_report.p99_latency_s > 0.0 && tight_report.p99_latency_s <= 0.050 + 1e-9,
        "p99 must reflect the late emission without exaggerating it (got {})",
        tight_report.p99_latency_s
    );

    let stats = server.stats().expect("stats");
    let session_sum: u64 = stats.sessions.iter().map(|s| s.report.slo_miss).sum();
    assert_eq!(session_sum, 2);
    assert_eq!(
        stats.aggregate.slo_miss, session_sum,
        "aggregate slo_miss must equal the per-session sum"
    );

    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.slo_miss, 2, "the terminal aggregate keeps the same accounting");
}

/// Gate 3a: the in-flight cap. The third un-drained submission is a
/// quota rejection — `dropped_quota`, not `dropped` — and draining the
/// stream frees slots again.
#[test]
fn inflight_quota_rejections_count_dropped_quota_not_dropped() {
    let (server, _manual) = manual_server(1);
    let mut session = server
        .session(
            SessionOptions::named("capped")
                .with_queue_depth(8)
                .with_quota(Quota::inflight(2)),
        )
        .expect("session");

    let mut fs = frames(4).into_iter();
    assert_eq!(session.try_submit(fs.next().unwrap()), PushOutcome::Queued);
    assert_eq!(session.try_submit(fs.next().unwrap()), PushOutcome::Queued);
    // In-flight = submitted − consumed = 2: the cap binds no matter how
    // fast the worker ran, because nothing was drained yet.
    assert_eq!(
        session.try_submit(fs.next().unwrap()),
        PushOutcome::Quota,
        "the third un-drained submission must be a quota rejection"
    );
    {
        let report = session.report();
        assert_eq!(report.dropped_quota, 1, "exactly one quota rejection");
        assert_eq!(report.dropped, 0, "a policy drop must never count as backpressure");
    }
    // Draining two results frees the in-flight slots.
    for _ in 0..2 {
        (&mut session).next().expect("result").expect("ok");
    }
    assert_eq!(session.try_submit(fs.next().unwrap()), PushOutcome::Queued);
    session.close();
    let report = session.finish().expect("drain");
    assert_eq!(report.frames, 3);
    assert_eq!(report.dropped_quota, 1);
    assert_eq!(report.dropped, 0);
    server.shutdown().expect("shutdown");
}

/// Gate 3b: the token-bucket rate quota, refilled purely by manual-clock
/// advances — 1 fps with burst 1 admits exactly one frame per advanced
/// second, and every early attempt is a distinct `dropped_quota`.
#[test]
fn rate_quota_refills_only_with_the_clock() {
    let (server, manual) = manual_server(1);
    let mut session = server
        .session(
            SessionOptions::named("metered")
                .with_queue_depth(8)
                .with_quota(Quota::rate(1.0, 1)),
        )
        .expect("session");

    let mut fs = frames(4).into_iter();
    assert_eq!(session.try_submit(fs.next().unwrap()), PushOutcome::Queued, "burst token");
    assert_eq!(
        session.try_submit(fs.next().unwrap()),
        PushOutcome::Quota,
        "no manual time passed, so no token can exist"
    );
    manual.advance(Duration::from_secs(1));
    assert_eq!(session.try_submit(fs.next().unwrap()), PushOutcome::Queued, "refilled token");
    assert_eq!(session.try_submit(fs.next().unwrap()), PushOutcome::Quota);

    session.close();
    let report = session.finish().expect("drain");
    assert_eq!(report.frames, 2, "exactly one admission per advanced second");
    assert_eq!(report.dropped_quota, 2);
    assert_eq!(report.dropped, 0);
    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.dropped_quota, 2, "the aggregate carries the quota accounting");
}

/// Worker whose warmup blocks on a permit — holding the dispatcher
/// pre-ready while submissions queue — and records the exact order it
/// processes frames in.
struct GatedWorker {
    inner: BatchEchoWorker,
    permit: Arc<Mutex<Option<std::sync::mpsc::Receiver<()>>>>,
    order: Arc<Mutex<Vec<u64>>>,
}

impl FrameWorker for GatedWorker {
    fn warmup(&mut self) -> Result<()> {
        let rx = self.permit.lock().unwrap().take().expect("one worker, one permit");
        rx.recv().ok();
        Ok(())
    }

    fn process(&mut self, frame: &Frame) -> Result<FrameResult> {
        self.order.lock().unwrap().push(frame.index);
        self.inner.process(frame)
    }

    fn process_batch(&mut self, batch: &[Frame]) -> Result<Vec<FrameResult>> {
        for f in batch {
            self.order.lock().unwrap().push(f.index);
        }
        self.inner.process_batch(batch)
    }

    fn take_metrics(&mut self) -> StageMetrics {
        self.inner.take_metrics()
    }
}

/// Gate 4: earliest-deadline-first admission. The loose-SLO session
/// (1 s) submits strictly before the tight-SLO session (10 ms) while the
/// lone worker is still gated in warmup; once the worker warms, the
/// dispatcher's EDF pre-pass must admit the tight frame first — plain
/// weighted round-robin order would have served the loose session's
/// earlier-registered entry first. Deterministic: the clock is frozen
/// (both `accepted_at`s are identical, only the SLOs differ) and batch
/// size 1 makes worker processing order equal admission order.
#[test]
fn edf_admits_imminent_deadline_before_admission_order() {
    let (permit_tx, permit_rx) = std::sync::mpsc::channel::<()>();
    let permit = Arc::new(Mutex::new(Some(permit_rx)));
    let order: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let (clock, _manual) = Clock::manual();
    let mut cfg = EngineConfig::new(1, PATCH_PX, 96);
    cfg.clock = clock;
    cfg.batch = BatchPolicy::batched(1, Duration::from_secs(3600));
    cfg.warmup_timeout_s = 24.0 * 3600.0;
    cfg.stall_timeout_s = 24.0 * 3600.0;
    let server = {
        let permit = permit.clone();
        let order = order.clone();
        Server::start(
            move |_wid| {
                Ok(GatedWorker {
                    inner: BatchEchoWorker::new(),
                    permit: permit.clone(),
                    order: order.clone(),
                })
            },
            cfg,
        )
        .expect("server")
    };

    // Registration and submission order: loose strictly first.
    let mut loose = server
        .session(SessionOptions::named("loose").with_queue_depth(8).with_slo(Duration::from_secs(1)))
        .expect("loose");
    let mut tight = server
        .session(
            SessionOptions::named("tight")
                .with_queue_depth(8)
                .with_slo(Duration::from_millis(10)),
        )
        .expect("tight");
    let template = frames(1).remove(0);
    let mut f_loose = template.clone();
    f_loose.index = 100;
    let mut f_tight = template;
    f_tight.index = 200;
    loose.submit(f_loose).expect("loose submit");
    tight.submit(f_tight).expect("tight submit");

    // Both frames are queued with identical accepted_at stamps; release
    // the worker and let the dispatcher's first sweep order them.
    permit_tx.send(()).expect("release warmup");
    (&mut tight).next().expect("tight result").expect("tight ok");
    (&mut loose).next().expect("loose result").expect("loose ok");
    assert_eq!(
        *order.lock().unwrap(),
        vec![200, 100],
        "the 10 ms deadline must be admitted before the 1 s one, despite admission order"
    );

    tight.close();
    loose.close();
    assert_eq!(tight.finish().expect("tight drain").frames, 1);
    assert_eq!(loose.finish().expect("loose drain").frames, 1);
    server.shutdown().expect("shutdown");
}
