//! Loom model-checking of the serving stack's two hand-rolled
//! concurrency protocols. Compiled (and run) only under
//! `RUSTFLAGS="--cfg loom" cargo test -p optovit --test loom_models`
//! — the CI model-checking lane; an ordinary `cargo test` builds this
//! target empty.
//!
//! 1. The [`optovit::coordinator::HealthSlot`] publication protocol
//!    (`coordinator/health.rs`): payload stored Relaxed, then the
//!    `at_risk` flag and `updates` tick stored Release; readers Acquire
//!    the flag/tick before any payload read. The models below check the
//!    real type (its atomics come from the `crate::util::sync` seam, so
//!    under `--cfg loom` they are loom atomics) across every
//!    interleaving: a reader that observes the flag or the tick must
//!    also observe the payload behind it. Weakening either Release
//!    store, or the readers' Acquire loads, makes these models fail.
//!
//! 2. The generation-counted wait of `coordinator/clock.rs::Event`. The
//!    real `Event` is built on `std` primitives (it must block real OS
//!    threads in production), so the model checks a line-for-line
//!    replica of its locking discipline built on loom primitives: the
//!    generation bump happens *under the wait lock*, which is exactly
//!    what makes the snapshot → predicate-recheck → wait pattern immune
//!    to a notify landing between the recheck and the wait. If the bump
//!    moved outside the lock, the waiter could sleep through the only
//!    notify and the model would deadlock (which loom reports).
#![cfg(loom)]

use loom::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

use optovit::coordinator::HealthSlot;

/// A reader that observes `at_risk == true` must also observe the
/// degraded health payload published alongside it — the dispatcher
/// routes on the flag and reports the payload, and they must never
/// tear apart.
#[test]
fn health_slot_at_risk_flag_carries_payload() {
    loom::model(|| {
        let slot = Arc::new(HealthSlot::new());
        let writer = slot.clone();
        let t = thread::spawn(move || {
            writer.publish(0.25, true);
        });
        if slot.at_risk() {
            assert_eq!(
                slot.health_value(),
                0.25,
                "at_risk observed without the degraded payload behind it"
            );
        }
        t.join().unwrap();
    });
}

/// A snapshot that observes publish tick `n` must observe everything
/// publish `n` wrote — this is what lets tests synchronize on "the
/// worker has republished" by polling `updates` instead of sleeping.
#[test]
fn health_slot_updates_tick_carries_payload() {
    loom::model(|| {
        let slot = Arc::new(HealthSlot::new());
        let writer = slot.clone();
        let t = thread::spawn(move || {
            writer.publish(0.5, false);
        });
        let snap = slot.snapshot(0, 0);
        if snap.updates >= 1 {
            assert_eq!(snap.health, 0.5, "tick observed without the payload publish {} wrote", 1);
        }
        t.join().unwrap();
    });
}

/// Successive publishes from the single writer stay coherent: a reader
/// that observes the second tick observes the second payload, never a
/// fresh tick over a stale health value.
#[test]
fn health_slot_republish_is_coherent() {
    loom::model(|| {
        let slot = Arc::new(HealthSlot::new());
        let writer = slot.clone();
        let t = thread::spawn(move || {
            writer.publish(0.5, true);
            writer.publish(0.25, true);
        });
        let snap = slot.snapshot(0, 0);
        if snap.updates >= 2 {
            assert_eq!(snap.health, 0.25, "second tick observed with a stale payload");
        } else if snap.updates == 1 && snap.at_risk {
            assert!(
                snap.health == 0.5 || snap.health == 0.25,
                "first tick observed with a health value no publish wrote: {}",
                snap.health
            );
        }
        t.join().unwrap();
    });
}

/// Replica of `coordinator/clock.rs::Event`'s locking discipline, on
/// loom primitives. Field-for-field mirror of the system-clock variant:
/// `gen` is the notify generation, and `notify` bumps it *while holding
/// the wait lock* before broadcasting.
struct EventModel {
    gen: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
}

impl EventModel {
    fn new() -> Self {
        EventModel { gen: AtomicU64::new(0), lock: Mutex::new(()), cv: Condvar::new() }
    }

    /// Mirror of `Event::generation` (Acquire snapshot).
    fn generation(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    /// Mirror of `Event::notify`: the bump happens under the wait lock,
    /// so it cannot land between a waiter's generation snapshot and its
    /// wait — the waiter either sees the new generation and returns
    /// immediately, or is already registered on the condvar.
    fn notify(&self) {
        let _g = self.lock.lock().unwrap();
        self.gen.fetch_add(1, Ordering::Release);
        self.cv.notify_all();
    }

    /// Mirror of the blocking core of `Event::wait_until` (the real one
    /// adds a clock deadline; liveness here is exactly the no-missed-
    /// notify property, so the model omits the timeout escape hatch —
    /// a lost notify shows up as a loom-reported deadlock).
    fn wait(&self, gen: u64) -> u64 {
        let mut g = self.lock.lock().unwrap();
        while self.generation() == gen {
            g = self.cv.wait(g).unwrap();
        }
        drop(g);
        self.generation()
    }
}

/// The race-free usage pattern from the `Event` docs: snapshot the
/// generation, re-check the predicate, then wait. Whatever interleaving
/// the notifier lands in, the waiter must terminate and observe the
/// predicate — a notify between the recheck and the wait must not be
/// missed (if it were, the model deadlocks and loom fails the test).
#[test]
fn event_generation_wait_never_misses_notify() {
    loom::model(|| {
        let ev = Arc::new(EventModel::new());
        let ready = Arc::new(AtomicBool::new(false));
        let (ev2, ready2) = (ev.clone(), ready.clone());
        let t = thread::spawn(move || {
            ready2.store(true, Ordering::Release);
            ev2.notify();
        });
        loop {
            let gen = ev.generation();
            if ready.load(Ordering::Acquire) {
                break;
            }
            ev.wait(gen);
        }
        t.join().unwrap();
    });
}

/// A notify that lands *before* the waiter's snapshot is not lost
/// either: a wait on a stale generation returns immediately instead of
/// blocking on a broadcast that already happened.
#[test]
fn event_stale_generation_returns_immediately() {
    loom::model(|| {
        let ev = Arc::new(EventModel::new());
        let ev2 = ev.clone();
        let t = thread::spawn(move || {
            ev2.notify();
        });
        t.join().unwrap();
        // The notify is fully ordered before this point (thread join);
        // waiting on the pre-notify generation must not block.
        let after = ev.wait(0);
        assert_eq!(after, 1, "stale snapshot returns at once with the bumped generation");
    });
}
