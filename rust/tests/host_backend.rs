//! End-to-end serving over the artifact-free backends — the tier-1 CI gate
//! for the full frame path (patchify → MGNet → mask → bucket → backbone →
//! reassembly) with no Python and no compiled HLO on disk.
//!
//! This binary installs the counting allocator and holds a **single test**
//! so the per-frame allocation bound is measured on a quiet process
//! (parallel sibling tests would pollute the process-wide counter — the
//! same discipline as `alloc_hot_path.rs`).

use std::time::Duration;

use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::engine::{run, serve_sharded, EngineConfig};
use optovit::coordinator::pipeline::{serve, Pipeline, PipelineConfig, ServeOptions};
use optovit::coordinator::BucketRouter;
use optovit::runtime::{Backend, HostBackend, HostConfig, HostFactory, SimBackend};
use optovit::sensor::VideoSource;
use optovit::util::bench::{count_allocations, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Debug-mode forwards are slow; one encoder block exercises the full
/// dataflow (embed → attention w/ validity mask → FFN → head) at CI cost.
fn host_cfg() -> HostConfig {
    HostConfig { depth_limit: Some(1), ..HostConfig::default() }
}

#[test]
fn host_backend_serves_end_to_end() {
    let cfg = PipelineConfig::tiny_96();
    let router = BucketRouter::new(cfg.buckets.clone());

    // --- 1. single-pipeline serve: full masked path, no artifacts.
    //     `serve` streams; `finish` drains the stream into the report ---
    let mut p = Pipeline::with_backend(cfg.clone(), HostBackend::new(host_cfg())).expect("pipeline");
    let opts8 = ServeOptions { sensor_seed: 7, ..ServeOptions::frames(8) };
    let report = serve(&mut p, &opts8).expect("host serve").finish().expect("drain");
    assert_eq!(report.backend, "host", "ServeReport must identify the backend");
    assert_eq!(report.frames, 8);
    assert_eq!(report.workers, 1);
    assert_eq!(report.mean_batch, 1.0, "per-frame policy means batch size 1");
    assert!(report.mean_latency_s > 0.0);
    assert!(report.mean_energy_j > 0.0, "modeled energy is charged on every backend");
    assert!((1.0..=36.0).contains(&report.mean_kept_patches), "{}", report.mean_kept_patches);
    assert!((0.0..=1.0).contains(&report.mean_mask_iou));
    assert!((0.0..=1.0).contains(&report.top1_accuracy));

    // --- 2. alloc-bounded hot path on a quiet process: the staging stages
    //     stay off the heap, so a steady-state frame costs only the
    //     backend's output vectors and the cloned result mask ---
    let mut sensor = VideoSource::new(96, 2, 5);
    for _ in 0..2 {
        p.process_frame(&sensor.next_frame()).expect("warm frame");
    }
    for _ in 0..3 {
        let frame = sensor.next_frame();
        let (r, allocs) = count_allocations(|| p.process_frame(&frame).expect("steady frame"));
        assert_eq!(r.logits.len(), 10);
        assert!(
            allocs <= 16,
            "steady-state host frame performed {allocs} allocations — the \
             pre-backend staging hot path must be allocation-free"
        );
    }

    // --- 3. sharded engine (workers = 2): in-order emission and
    //     mask/bucket accounting on every result ---
    let mut ecfg = EngineConfig::new(2, 16, 96);
    ecfg.warmup_timeout_s = 60.0;
    ecfg.stall_timeout_s = 30.0;
    let mut seen: Vec<(u64, usize, usize)> = Vec::new();
    // The factory moves into the server's worker threads now, so it owns
    // its own copy of the pipeline config.
    let engine_cfg = cfg.clone();
    let (sharded, merged) = run(
        move |_wid| Pipeline::with_backend(engine_cfg.clone(), HostBackend::new(host_cfg())),
        &ecfg,
        12,
        |r| seen.push((r.frame_index, r.bucket, r.mask.kept())),
    )
    .expect("sharded host run");
    assert_eq!(sharded.backend, "host");
    assert_eq!(sharded.workers, 2);
    assert_eq!(sharded.frames, 12);
    assert_eq!(seen.len(), 12);
    assert_eq!(merged.frames(), 12);
    assert_eq!(sharded.per_worker.len(), 2);
    assert_eq!(sharded.per_worker.iter().map(|w| w.frames).sum::<u64>(), 12);
    for pair in seen.windows(2) {
        assert!(pair[0].0 < pair[1].0, "results out of dispatch order: {seen:?}");
    }
    for &(idx, bucket, kept) in &seen {
        assert!(cfg.buckets.contains(&bucket), "frame {idx}: bucket {bucket} not in ladder");
        assert_eq!(
            bucket,
            router.route(kept.max(1)),
            "frame {idx}: bucket/kept accounting mismatch (kept {kept})"
        );
        assert!(kept <= 36, "frame {idx}: kept {kept} exceeds the grid");
    }

    // --- 4. serve_sharded: the public factory-based entry point ---
    let (r2, m2) = serve_sharded(&cfg, &HostFactory(host_cfg()), 2, &ServeOptions::frames(8))
        .expect("serve_sharded over HostBackend");
    assert_eq!(r2.backend, "host");
    assert_eq!(r2.frames, 8);
    assert_eq!(m2.frames(), 8);
    assert!(!m2.has_stage("modeled"), "host backend reports wall-clock latency");

    // --- 5. unmasked baseline still runs artifact-free ---
    let mut cfg_full = cfg.clone();
    cfg_full.use_mask = false;
    let mut pf = Pipeline::with_backend(cfg_full, HostBackend::new(host_cfg())).expect("pipeline");
    let opts3 = ServeOptions { sensor_seed: 11, ..ServeOptions::frames(3) };
    let rf = serve(&mut pf, &opts3).expect("no-mask host serve").finish().expect("drain");
    assert_eq!(rf.frames, 3);
    assert_eq!(rf.mean_kept_patches, 36.0, "no-mask runs keep the full grid");

    // --- 6. streaming + micro-batching: the stream yields in-order
    //     results one by one, the batcher groups frames bucket-major, and
    //     the drained stream still derives the full report ---
    let mut pb =
        Pipeline::with_backend(cfg.clone(), HostBackend::new(host_cfg())).expect("pipeline");
    let bopts = ServeOptions {
        sensor_seed: 7,
        batch: BatchPolicy::batched(4, Duration::from_millis(2)),
        window: 8,
        ..ServeOptions::frames(10)
    };
    let mut stream = serve(&mut pb, &bopts).expect("batched serve stream");
    let mut indices = Vec::new();
    let first = stream.next().expect("stream yields").expect("first result");
    indices.push(first.frame_index);
    // The reassembly buffer is bounded by the window plus at most one
    // force-flushed group.
    assert!(stream.buffered() <= 8 + 4, "reassembly buffer must respect the window");
    for r in &mut stream {
        indices.push(r.expect("streamed result").frame_index);
    }
    let rb = stream.finish().expect("report from drained stream");
    assert_eq!(rb.frames, 10);
    assert_eq!(indices.len(), 10);
    for pair in indices.windows(2) {
        assert!(pair[0] < pair[1], "stream must emit in order: {indices:?}");
    }
    assert!(rb.mean_batch >= 1.0, "mean batch must be recorded ({})", rb.mean_batch);

    // --- 7. sim backend: same numerics, modeled photonic latency,
    //     recorded per stage ---
    let mut ps =
        Pipeline::with_backend(cfg.clone(), SimBackend::new(host_cfg())).expect("sim pipeline");
    let opts4 = ServeOptions { sensor_seed: 7, ..ServeOptions::frames(4) };
    let rs = serve(&mut ps, &opts4).expect("sim serve").finish().expect("drain");
    assert_eq!(rs.backend, "sim");
    assert_eq!(rs.frames, 4);
    assert!(ps.metrics.has_stage("modeled"), "sim must charge modeled frame latency");
    assert!(
        ps.metrics.has_stage("modeled_mgnet") && ps.metrics.has_stage("modeled_backbone"),
        "sim must charge MGNet and backbone latency as separate stages"
    );
    let stage_sum =
        ps.metrics.stage_mean_s("modeled_mgnet") + ps.metrics.stage_mean_s("modeled_backbone");
    assert!(
        (stage_sum - ps.metrics.stage_mean_s("modeled")).abs() <= stage_sum * 1e-9,
        "per-stage modeled latency must sum to the modeled total"
    );
    assert!(
        rs.mean_latency_s > 0.0 && rs.mean_latency_s.is_finite(),
        "modeled latency {} must be positive",
        rs.mean_latency_s
    );
    // Modeled latency is a property of the frame (kept count), not of the
    // host: replaying a frame charges the identical latency.
    let mut sensor = VideoSource::new(96, 2, 31);
    let frame = sensor.next_frame();
    let a = ps.process_frame(&frame).expect("sim frame");
    let b = ps.process_frame(&frame).expect("sim frame replay");
    assert_eq!(a.latency_s, b.latency_s, "modeled latency must be deterministic");
    // And the sim numerics are exactly the host reference numerics.
    let mut ph =
        Pipeline::with_backend(cfg.clone(), HostBackend::new(host_cfg())).expect("host pipeline");
    ph.warmup().expect("host warmup");
    let h = ph.process_frame(&frame).expect("host frame");
    assert_eq!(a.logits, h.logits, "sim must reuse host numerics");
    assert_eq!(a.bucket, h.bucket);
    assert!(!ps.backend().needs_artifacts() && !ph.backend().needs_artifacts());
}
