//! Deterministic mixed-precision serving gate — the tier semantics the
//! precision tentpole promises, proven end-to-end with **exact** (not
//! threshold-fuzzy) expectations:
//!
//! 1. fixed-tier tenants beside an `Auto` tenant on one manual-clock
//!    server: every session's `tier_frames` is exactly its submission
//!    count in exactly its tier's slot, the live (`stats()`) and terminal
//!    (`shutdown()`) aggregates equal the element-wise per-session sums,
//!    modeled energy/frame orders strictly `int4 < int8 < fp32` on
//!    identical frame content, and fp32 agreement accounting stays inside
//!    its bounds (`tier_agree[i] <= tier_ref_frames[i]`, ratio in 0..=1,
//!    no probes charged to the fp32 tier itself);
//! 2. `Auto` resolves from ROI density end-to-end through the streaming
//!    `serve` path: an all-kept mask (`region_threshold` 0) serves every
//!    frame at INT8, a best-patch-fallback mask (`region_threshold` 1)
//!    serves every frame at INT4 — and that INT4 run is strictly cheaper
//!    per frame than uniform INT8 over the same frames;
//! 3. micro-batch groups are tier-separated: a worker group holding two
//!    INT4 and two INT8 frames of identical content (same bucket) must
//!    execute as two single-tier sub-batches of 2, never one mixed batch
//!    of 4.

use std::time::Duration;

use optovit::coordinator::batcher::BatchPolicy;
use optovit::coordinator::clock::Clock;
use optovit::coordinator::engine::EngineConfig;
use optovit::coordinator::pipeline::{serve, Pipeline, PipelineConfig, ServeOptions};
use optovit::coordinator::server::{Server, SessionOptions};
use optovit::quant::{PrecisionPolicy, PrecisionTier};
use optovit::runtime::{HostBackend, HostConfig};
use optovit::sensor::{Frame, VideoSource};

const PATCH_PX: usize = 16;

/// One encoder block keeps debug-mode host forwards cheap while
/// exercising the full tiered dataflow (embed → attention → FFN → head
/// per tier, plus the fp32 reference probe).
fn host_cfg() -> HostConfig {
    HostConfig { depth_limit: Some(1), ..HostConfig::default() }
}

/// One Pipeline-backed worker on a frozen manual clock: groups flush by
/// count only, so tier accounting never depends on wall time. The
/// pipeline workers (not echo mocks) are the point — tier resolution,
/// tiered execution, and the fp32 probe all run for real.
fn manual_pipeline_server(pipe_cfg: PipelineConfig, batch: BatchPolicy) -> Server {
    let (clock, _manual) = Clock::manual();
    let mut cfg = EngineConfig::new(1, PATCH_PX, 96);
    cfg.clock = clock;
    cfg.batch = batch;
    // Manual time never advances in these tests; generous bounds keep
    // the watchdogs out of the way.
    cfg.warmup_timeout_s = 24.0 * 3600.0;
    cfg.stall_timeout_s = 24.0 * 3600.0;
    let server = Server::start(
        move |_wid| Pipeline::with_backend(pipe_cfg.clone(), HostBackend::new(host_cfg())),
        cfg,
    )
    .expect("server");
    server.wait_ready(Duration::from_secs(3600)).expect("workers warm");
    server
}

/// Identical frame content with distinct indices: every submission
/// resolves the same mask and routes to the same bucket, so tier is the
/// *only* thing that differs between tenants.
fn frames(n: u64) -> Vec<Frame> {
    let template = VideoSource::new(96, 2, 42).next_frame();
    (0..n)
        .map(|i| {
            let mut f = template.clone();
            f.index = i;
            f
        })
        .collect()
}

fn fixed(tier: PrecisionTier) -> PrecisionPolicy {
    PrecisionPolicy::Fixed(tier)
}

/// Gate 1: exact per-tier accounting across fixed-tier tenants and an
/// `Auto` tenant, aggregate == element-wise session sum (live and
/// terminal), strict per-frame energy ordering, and agreement bounds.
#[test]
fn fixed_and_auto_tenants_account_exactly_per_tier() {
    let mut pipe_cfg = PipelineConfig::tiny_96();
    // All patches kept → `Auto` sees kept_frac 1.0 and must resolve INT8
    // for every frame: the Auto tenant's tier counts become exact.
    pipe_cfg.region_threshold = 0.0;
    pipe_cfg.fp32_reference = true;
    let server = manual_pipeline_server(pipe_cfg, BatchPolicy::per_frame());

    let counts: [u64; 4] = [3, 4, 2, 5];
    let opts = [
        ("int4", fixed(PrecisionTier::Int4)),
        ("int8", fixed(PrecisionTier::Int8)),
        ("fp32", fixed(PrecisionTier::Fp32)),
        ("auto", PrecisionPolicy::Auto),
    ];
    let mut sessions = Vec::new();
    for (i, (name, policy)) in opts.iter().enumerate() {
        let mut s = server
            .session(SessionOptions::named(name).with_queue_depth(8).with_precision(*policy))
            .expect("session");
        for f in frames(counts[i]) {
            s.submit(f).expect("submit");
        }
        s.close();
        sessions.push(s);
    }

    // Drain each tenant, recording the served tier and modeled energy of
    // every result.
    let mut energy = [f64::NAN; 4];
    let expect_tier =
        [PrecisionTier::Int4, PrecisionTier::Int8, PrecisionTier::Fp32, PrecisionTier::Int8];
    for (i, s) in sessions.iter_mut().enumerate() {
        let mut served = 0u64;
        for item in &mut *s {
            let r = item.expect("result");
            assert_eq!(
                r.tier, expect_tier[i],
                "tenant {} must serve every frame at its resolved tier",
                opts[i].0
            );
            // Identical frames at one tier and batch 1: identical energy.
            if served == 0 {
                energy[i] = r.modeled_energy_j;
            } else {
                assert!(
                    (r.modeled_energy_j - energy[i]).abs() < 1e-18,
                    "identical frames at one tier must charge identical energy"
                );
            }
            served += 1;
        }
        assert_eq!(served, counts[i]);
    }

    // Strict tier economics on identical content: every conversion and
    // weight-programming share scales with the tier, so the ordering has
    // no ties.
    assert!(
        energy[0] < energy[1] && energy[1] < energy[2],
        "modeled energy/frame must order strictly int4 < int8 < fp32, got {energy:?}"
    );

    // Exact per-session tier accounting, probes included: every integer-
    // tier frame is probed (fp32_reference is on), the fp32 tenant never
    // is (it *is* the reference).
    let expect_frames =
        [[counts[0], 0, 0], [0, counts[1], 0], [0, 0, counts[2]], [0, counts[3], 0]];
    let expect_refs = [[counts[0], 0, 0], [0, counts[1], 0], [0, 0, 0], [0, counts[3], 0]];
    for (i, s) in sessions.iter().enumerate() {
        let report = s.report();
        assert_eq!(report.tier_frames, expect_frames[i], "tenant {} tier_frames", opts[i].0);
        assert_eq!(report.tier_ref_frames, expect_refs[i], "tenant {} tier_ref_frames", opts[i].0);
        for t in 0..3 {
            assert!(
                report.tier_agree[t] <= report.tier_ref_frames[t],
                "agreement can never exceed the probe count"
            );
        }
        for tier in PrecisionTier::ALL {
            if let Some(a) = report.tier_agreement(tier) {
                assert!((0.0..=1.0).contains(&a), "agreement ratio out of bounds: {a}");
            }
        }
        if report.tier_ref_frames == [0, 0, 0] {
            assert_eq!(
                report.tier_agreement(expect_tier[i]),
                None,
                "unprobed tiers must report no agreement, not a fake 0 or 1"
            );
        }
    }

    // Live aggregate == element-wise per-session sum.
    let stats = server.stats().expect("stats");
    assert_eq!(stats.sessions.len(), 4);
    let mut sum_frames = [0u64; 3];
    let mut sum_refs = [0u64; 3];
    let mut sum_agree = [0u64; 3];
    for s in &stats.sessions {
        for t in 0..3 {
            sum_frames[t] += s.report.tier_frames[t];
            sum_refs[t] += s.report.tier_ref_frames[t];
            sum_agree[t] += s.report.tier_agree[t];
        }
    }
    assert_eq!(stats.aggregate.tier_frames, sum_frames, "aggregate tier_frames != session sum");
    assert_eq!(stats.aggregate.tier_ref_frames, sum_refs);
    assert_eq!(stats.aggregate.tier_agree, sum_agree);
    assert_eq!(sum_frames, [counts[0], counts[1] + counts[3], counts[2]]);
    assert_eq!(
        sum_frames.iter().sum::<u64>(),
        stats.aggregate.frames,
        "tier_frames must partition the served frames"
    );

    // Terminal aggregate carries the same exact arrays.
    drop(sessions);
    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.tier_frames, sum_frames);
    assert_eq!(agg.tier_ref_frames, sum_refs);
    assert_eq!(agg.tier_agree, sum_agree);
}

/// Gate 2: `Auto` follows ROI density through the streaming `serve`
/// path, and the background-heavy INT4 resolution is strictly cheaper
/// than uniform INT8 over the very same frames.
#[test]
fn auto_tier_follows_roi_density_and_beats_uniform_int8() {
    const FRAMES: u64 = 6;
    let run = |threshold: f32, policy: PrecisionPolicy| {
        let mut cfg = PipelineConfig::tiny_96();
        cfg.region_threshold = threshold;
        let mut pipeline =
            Pipeline::with_backend(cfg, HostBackend::new(host_cfg())).expect("pipeline");
        let opts = ServeOptions { precision: policy, ..ServeOptions::frames(FRAMES) };
        serve(&mut pipeline, &opts).expect("serve").finish().expect("finish")
    };

    // Threshold 0: every patch kept, kept_frac 1.0 ≥ AUTO_ROI_THRESHOLD
    // → INT8 for every frame.
    let dense = run(0.0, PrecisionPolicy::Auto);
    assert_eq!(dense.tier_frames, [0, FRAMES, 0], "all-kept masks must serve INT8");

    // Threshold 1: sigmoid scores never reach 1.0, so the mask is empty
    // and the router's best-patch fallback keeps exactly one patch —
    // kept_frac 1/36 < AUTO_ROI_THRESHOLD → INT4 for every frame.
    let sparse = run(1.0, PrecisionPolicy::Auto);
    assert_eq!(sparse.tier_frames, [FRAMES, 0, 0], "background-heavy masks must serve INT4");

    // Same frames, same masks, uniform INT8 instead: `Auto` must be
    // strictly cheaper per frame — that saving is the tentpole's claim.
    let uniform = run(1.0, fixed(PrecisionTier::Int8));
    assert_eq!(uniform.tier_frames, [0, FRAMES, 0]);
    assert!(
        sparse.mean_energy_j < uniform.mean_energy_j,
        "auto (int4) must be strictly cheaper than uniform int8: {} vs {}",
        sparse.mean_energy_j,
        uniform.mean_energy_j
    );
}

/// Gate 3: tier separation inside a micro-batch group. Two INT4 and two
/// INT8 frames of identical content share one worker group of 4 (frozen
/// clock, `max_batch` 4 — the group can only flush by count), and the
/// pipeline must execute them as two single-tier sub-batches of 2.
#[test]
fn worker_groups_split_by_tier_into_single_tier_batches() {
    let mut pipe_cfg = PipelineConfig::tiny_96();
    pipe_cfg.region_threshold = 0.0;
    let server =
        manual_pipeline_server(pipe_cfg, BatchPolicy::batched(4, Duration::from_secs(3600)));

    let mut int4 = server
        .session(
            SessionOptions::named("int4")
                .with_queue_depth(8)
                .with_precision(fixed(PrecisionTier::Int4)),
        )
        .expect("int4 session");
    let mut int8 = server
        .session(
            SessionOptions::named("int8")
                .with_queue_depth(8)
                .with_precision(fixed(PrecisionTier::Int8)),
        )
        .expect("int8 session");

    // All four frames land in one bucket; with the clock frozen the
    // worker tops its group up to the full max_batch before executing.
    for f in frames(2) {
        int4.submit(f).expect("int4 submit");
    }
    for f in frames(2) {
        int8.submit(f).expect("int8 submit");
    }
    int4.close();
    int8.close();

    for (sess, tier) in [(&mut int4, PrecisionTier::Int4), (&mut int8, PrecisionTier::Int8)] {
        for item in &mut *sess {
            let r = item.expect("result");
            assert_eq!(r.tier, tier);
            assert_eq!(
                r.batch_size, 2,
                "a mixed-tier group of 4 must execute as single-tier sub-batches of 2"
            );
        }
    }
    let report4 = int4.report();
    let report8 = int8.report();
    assert_eq!(report4.tier_frames, [2, 0, 0]);
    assert_eq!(report8.tier_frames, [0, 2, 0]);
    assert!((report4.mean_batch - 2.0).abs() < 1e-12, "int4 mean_batch must be exactly 2");
    assert!((report8.mean_batch - 2.0).abs() < 1e-12, "int8 mean_batch must be exactly 2");

    drop(int4);
    drop(int8);
    let (agg, _metrics) = server.shutdown().expect("shutdown");
    assert_eq!(agg.tier_frames, [2, 2, 0]);
}
